"""Characterization scenario: run three scopes, merge with scope_plot cat,
filter, and produce a comparison bar chart — the paper's Fig. 1 data flow
(SCOPE binary -> JSON -> ScopePlot) as a script.

Run:  PYTHONPATH=src python examples/characterize.py
"""
import json
import os

from repro.core import REGISTRY, RunOptions, run_benchmarks
from repro.core.scope import ScopeManager
from repro.scopeplot import BenchmarkFile, cat
from repro.scopeplot.plot import quick_bar


def run_scope(name):
    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load([f"repro.scopes.{name}_scope"])
    mgr.register_all()
    doc = run_benchmarks(REGISTRY.filter(".*"), RunOptions(min_time=0.02),
                         progress=False)
    return BenchmarkFile.from_dict(doc)


def main():
    os.makedirs("results", exist_ok=True)
    merged = cat([run_scope(n) for n in ("instr", "histo", "linalg")])
    merged.save("results/characterize.json")
    print(f"{len(merged)} records from 3 scopes -> results/characterize.json")
    fast = merged.without_errors().filter_name("instr/")
    frame = fast.to_frame(["name", "real_time"])
    print(frame.sort_by("real_time").to_csv())
    out = quick_bar("results/characterize.json", "name", "real_time",
                    title="instr scope op latencies",
                    output="results/characterize.png", regex="instr/")
    print("wrote", out)


if __name__ == "__main__":
    main()
