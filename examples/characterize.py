"""Characterization scenario: run three scopes, merge with scope_plot cat,
filter/select by typed parameter, and produce comparison charts — the
paper's Fig. 1 data flow (SCOPE binary -> JSON -> ScopePlot) as a script.

The instr scope's ops are one typed family (``instr/elementwise`` with
an ``op`` axis), so the per-op latency chart comes from a single
``group_by`` spec series instead of a regex per family clone, and the
compile-vs-steady-state split the runner measures is printed per
instance.

Run:  PYTHONPATH=src python examples/characterize.py
"""
import json
import os

from repro.core import REGISTRY, RunOptions, run_benchmarks
from repro.core.scope import ScopeManager
from repro.scopeplot import BenchmarkFile, cat
from repro.scopeplot.plot import quick_bar, render_spec


def run_scope(name, param_filter=None):
    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load([f"repro.scopes.{name}_scope"])
    mgr.register_all()
    doc = run_benchmarks(REGISTRY.filter(".*"),
                         RunOptions(min_time=0.02,
                                    param_filter=param_filter),
                         progress=False)
    return BenchmarkFile.from_dict(doc)


def main():
    os.makedirs("results", exist_ok=True)
    merged = cat([run_scope(n) for n in ("instr", "histo", "linalg")])
    merged.save("results/characterize.json")
    print(f"{len(merged)} records from 3 scopes -> results/characterize.json")

    # typed-parameter selection on the loaded document: the same
    # axis:value components `--param op=exp` selects at run time
    fast = merged.without_errors().filter_params({"op": ["exp", "tanh"]})
    frame = fast.to_frame(["name", "real_time", "compile_time_s"])
    print(frame.sort_by("real_time").to_csv())

    # compile vs steady state, per instance (the runner's warm phase)
    for rec in merged.without_errors().without_aggregates():
        ct = rec.get("compile_time_s")
        if ct is not None:
            steady = rec.real_time_seconds() or 0.0
            print(f"{rec.name}: compile {ct * 1e3:.1f}ms, "
                  f"steady {steady * 1e6:.1f}us")

    out = quick_bar("results/characterize.json", "name", "real_time",
                    title="instr scope op latencies",
                    output="results/characterize.png", regex="instr/")
    print("wrote", out)

    # series-by-param: ONE spec series expands into a plotted series
    # per dtype of the single linalg/batched_matmul family
    out = render_spec({
        "title": "batched matmul by dtype",
        "type": "grouped_bar",
        "output": "results/characterize_dtypes.png",
        "x_axis": {"label": "n"},
        "y_axis": {"label": "time (us)"},
        "series": [{"input_file": "results/characterize.json",
                    "regex": "linalg/batched_matmul",
                    "group_by": "dtype", "xfield": "n"}],
    })
    print("wrote", out)


if __name__ == "__main__":
    main()
