"""Quickstart: the SCOPE workflow end-to-end in one minute.

1. register a custom benchmark through the core library (the Example|Scope
   integration surface);
2. run it through the SCOPE runner → Google-Benchmark JSON;
3. manipulate + plot the results with scopeplot.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import os

import jax
import jax.numpy as jnp

from repro.core import (REGISTRY, RunOptions, State, benchmark,
                        run_benchmarks, sync, write_json)
from repro.scopeplot import BenchmarkFile
from repro.scopeplot.plot import render_spec


def main():
    # -- 1. register ----------------------------------------------------
    @benchmark(scope="quickstart")
    def layer_norm(state: State):
        """Bench a jitted layer-norm across row counts."""
        n = state.range(0)
        x = jnp.ones((n, 512))
        fn = jax.jit(lambda x: (x - x.mean(-1, keepdims=True))
                     / (x.std(-1, keepdims=True) + 1e-6))
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_bytes_processed(2 * 4 * n * 512)
    layer_norm.range_multiplier_args(64, 4096, mult=4).set_arg_names(["rows"])

    # -- 2. run -----------------------------------------------------------
    doc = run_benchmarks(REGISTRY.filter("quickstart"),
                         RunOptions(min_time=0.02))
    os.makedirs("results", exist_ok=True)
    write_json(doc, "results/quickstart.json")

    # -- 3. analyze + plot ------------------------------------------------
    bf = BenchmarkFile.from_dict(doc).without_errors()
    print("\nname,us,GB/s")
    for rec in bf:
        if rec.get("run_type") == "iteration":
            print(f"{rec.name},{rec.real_time:.2f},"
                  f"{rec.get('bytes_per_second', 0) / 1e9:.2f}")
    out = render_spec({
        "title": "layer_norm throughput",
        "type": "line",
        "output": "results/quickstart.png",
        "x_axis": {"label": "rows", "scale": "log"},
        "y_axis": {"label": "GB/s"},
        "series": [{"label": "layer_norm",
                    "input_file": "results/quickstart.json",
                    "regex": "quickstart/layer_norm", "xfield": "rows",
                    "yfield": "bytes_per_second", "yscale": 1e-9}],
    })
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
