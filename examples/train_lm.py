"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the production path: deterministic pipeline, pjit'd microbatched step,
async checkpointing with resume.  ~100M params = llama3.2-1b reduced to
d_model=512/8L with the full 128k vocab.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    out = train(
        "llama3.2-1b",
        steps=args.steps,
        global_batch=8,
        seq_len=256,
        lr=1e-3,
        microbatches=2,
        ckpt_dir=args.ckpt,
        ckpt_every=100,
        overrides=dict(num_layers=8, d_model=512, num_heads=8,
                       num_kv_heads=4, head_dim=64, d_ff=2048),
        reduced=False,
        log_every=25,
    )
    print(f"\ntrained {out['steps']} steps: "
          f"loss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"({out['tokens_per_s']:.0f} tok/s)")
    assert out["last_loss"] < out["first_loss"], "loss should decrease"


if __name__ == "__main__":
    main()
