"""Serve a small LM with batched requests through the continuous-batching
engine: submit a mixed-length workload, report TTFT/latency/throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.models import build, get_config
from repro.serve import ServeConfig, ServeEngine


def main():
    cfg = get_config("llama3.2-1b").reduced().override(num_layers=4)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, params, ServeConfig(
        max_batch=4, max_len=256, prompt_buckets=(16, 32, 64)))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(12):
        plen = int(rng.integers(4, 48))
        reqs.append(engine.submit(
            rng.integers(1, cfg.vocab_size, size=plen), max_tokens=24))
    done = engine.run()
    stats = ServeEngine.summarize(done)
    print("served:", stats)
    sample = done[0]
    print(f"request {sample.uid}: prompt[{len(sample.prompt)}] -> "
          f"{sample.output[:12]}...")


if __name__ == "__main__":
    main()
