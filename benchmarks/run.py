"""Benchmark harness — one section per paper table/figure.

  * Table IV (the scopes): every completed scope runs through the core
    run orchestrator (repro.core.orchestrate) — failure-isolated, and
    parallel across benchmark instances when ``BENCH_JOBS>1``; each
    benchmark instance prints ``name,us_per_call,derived`` where
    ``derived`` is the scope's natural rate (GB/s, Mitems/s, modeled
    seconds, ...).  The scope list is the ScopeManager's builtin set —
    new scopes join the harness by joining ``BUILTIN_SCOPES``, nothing
    here to update;
  * Figure 3 (ScopePlot line plot): regenerates the example saxpy plot
    from live results via the scopeplot spec pipeline;
  * §Roofline feed: the model scope surfaces the dry-run cells when
    results/dryrun exists.

Wall-clock numbers are CPU wall-clock on this container (framework
overhead + relative comparisons); TPU numbers are the modeled columns.

Env knobs: ``BENCH_JOBS`` (worker parallelism, default 1 → inline),
``BENCH_SHARD_GRAIN`` (``auto``/``benchmark``/``scope``),
``BENCH_PARAM`` (typed-parameter selection, space-separated
``key=value`` pairs — e.g. ``BENCH_PARAM="dtype=bf16 backend=xla"``
runs only matching instances of the typed parameter spaces),
``BENCH_RESULTS_DIR`` (persist shards + manifest + merged.json, and
append the run to ``<dir>/history.jsonl``), ``BENCH_BASELINE``
(baseline document/run dir/history.jsonl; adds a per-benchmark
``regression``/``improvement``/``similar`` verdict column),
``BENCH_REPORT`` (with BENCH_RESULTS_DIR: also render the run's
HTML/Markdown report — repro.scopeplot.report).
"""
import os


def _derived(rec) -> str:
    for key, scale, unit in (("bytes_per_second", 1e-9, "GB/s"),
                             ("items_per_second", 1e-6, "Mitems/s"),
                             ("modeled_s", 1e6, "modeled_us"),
                             ("cells", 1, "cells")):
        v = rec.raw.get(key)
        if v:
            return f"{v * scale:.3f}{unit}"
    ct = rec.raw.get("compile_time_s")
    if ct:
        # no natural rate: surface the warm-phase compile measurement
        return f"{ct * 1e3:.3f}compile_ms"
    return ""


def _print_shard(shard, verdicts=None) -> None:
    from repro.scopeplot import BenchmarkFile
    if shard.status not in ("ok", "partial") or shard.doc is None:
        first = shard.error.strip().splitlines()[-1] if shard.error else \
            shard.status
        print(f"{shard.scope}/SCOPE_FAILED,0.00,{first}")
        return
    bf = BenchmarkFile.from_dict(shard.doc)
    for rec in bf:
        if rec.raw.get("run_type") == "aggregate" or rec.raw.get("skipped"):
            continue
        if rec.raw.get("error_occurred"):
            # a failed instance must stay visible in the table — that is
            # the point of per-instance failure isolation
            msg = (rec.raw.get("error_message") or "error").strip()
            lines = msg.splitlines()
            # "[crashed] worker exited N:" leads; tracebacks end with the
            # exception — pick whichever line carries the signal
            derived = (lines[0] if msg.startswith("[crashed]")
                       else lines[-1]).replace(",", ";")
        else:
            derived = _derived(rec)
        us = rec.real_time_seconds()
        us = us * 1e6 if us is not None else float("nan")
        line = f"{rec.name},{us:.2f},{derived}"
        if verdicts is not None:
            run_name = rec.raw.get("run_name") or rec.name
            line += f",{verdicts.get(run_name, '')}"
        print(line)


def _baseline_verdicts(doc):
    """run_name → verdict against ``BENCH_BASELINE``; None when unset.

    A bad baseline path must not discard a finished run — degrade to no
    verdict column with a warning.
    """
    path = os.environ.get("BENCH_BASELINE")
    if not path:
        return None
    import json as _json
    import sys
    from repro.core.baseline import compare_documents, load_document
    try:
        base = load_document(path)
    except (OSError, _json.JSONDecodeError) as e:
        print(f"BENCH_BASELINE {path} unreadable ({e}); "
              f"skipping verdict column", file=sys.stderr)
        return None
    comps = compare_documents(base, doc)
    return {c.name: c.verdict for c in comps}


def run_all(min_time: float = 0.02):
    """Run every builtin scope through the orchestrator.

    Returns (RunResult, unavailable, scope_names) where ``unavailable``
    maps scopes that failed to import/register to their tracebacks — the
    orchestrator never schedules those, but the harness must still report
    them — and ``scope_names`` is the ScopeManager's load order, so the
    harness can't silently miss a scope the binary knows about.
    """
    from repro.core import REGISTRY, RunOptions, parse_param_filter
    from repro.core.orchestrate import OrchestratorOptions, execute
    from repro.core.scope import ScopeManager

    jobs = int(os.environ.get("BENCH_JOBS", "1"))
    try:
        param_filter = parse_param_filter(
            os.environ.get("BENCH_PARAM", "").split())
    except ValueError as e:
        import sys
        sys.exit(f"BENCH_PARAM: {e}")
    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load(None)                       # BUILTIN_SCOPES — the Table IV set
    mgr.register_all()
    scope_names = [s.scope.name for s in mgr.scopes()]
    opts = OrchestratorOptions(
        jobs=jobs,
        shard_grain=os.environ.get("BENCH_SHARD_GRAIN", "auto"),
        run=RunOptions(min_time=min_time, param_filter=param_filter),
        results_dir=os.environ.get("BENCH_RESULTS_DIR"),
    )
    result = execute(mgr, REGISTRY, opts,
                     context_extra={"scopes": mgr.status()})
    unavailable = {s.scope.name: s.error for s in mgr.scopes()
                   if not s.available}
    return result, unavailable, scope_names


def figure3_plot(docs) -> None:
    """Regenerate the paper's Fig. 3-style line plot via scopeplot."""
    import json
    import tempfile
    from repro.scopeplot.plot import render_spec
    ex = docs.get("example")
    if ex is None:
        return
    with tempfile.TemporaryDirectory() as d:
        src = os.path.join(d, "example.json")
        with open(src, "w") as f:
            json.dump(ex, f)
        spec = {
            "title": "saxpy throughput (Fig. 3 analogue)",
            "type": "line",
            "output": os.path.join("results", "fig3_saxpy.png"),
            "x_axis": {"label": "elements", "scale": "log"},
            "y_axis": {"label": "GB/s"},
            "series": [{"label": "saxpy", "input_file": src,
                        "regex": "example/saxpy", "xfield": "n",
                        "yfield": "bytes_per_second", "yscale": 1e-9}],
        }
        os.makedirs("results", exist_ok=True)
        out = render_spec(spec)
        print(f"fig3_plot,0.00,{out}")


def _report(result) -> None:
    """Render the run's report when BENCH_REPORT + BENCH_RESULTS_DIR ask
    for one.  Report failure must not fail the harness run."""
    if not (os.environ.get("BENCH_REPORT") and result.out_dir):
        return
    import sys
    try:
        from repro.scopeplot.report import generate_run_report
        paths = generate_run_report(result.out_dir)
        print(f"report,0.00,{paths['html']}")
    except Exception as e:  # noqa: BLE001 - artifact, not a gate
        print(f"BENCH_REPORT failed ({e}); skipping report",
              file=sys.stderr)


def main() -> None:
    result, unavailable, scopes = run_all()
    verdicts = _baseline_verdicts(result.doc)
    param_active = bool(os.environ.get("BENCH_PARAM", "").strip())
    docs = {}
    for scope in scopes:
        shard = result.shard(scope)
        if shard is None:
            if scope not in unavailable and param_active:
                # deselected, not broken: no instance matched BENCH_PARAM
                print(f"{scope}/SKIPPED,0.00,no instance matches "
                      f"BENCH_PARAM")
                continue
            err = unavailable.get(scope, "not scheduled")
            last = err.strip().splitlines()[-1] if err else "not scheduled"
            print(f"{scope}/SCOPE_FAILED,0.00,{last}")
            continue
        _print_shard(shard, verdicts)
        if shard.status in ("ok", "partial"):
            docs[scope] = shard.doc
    figure3_plot(docs)
    _report(result)


if __name__ == '__main__':
    main()
