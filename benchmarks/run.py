"""Benchmark harness — one section per paper table/figure.

  * Table IV (the scopes): every completed scope runs through the core
    run orchestrator (repro.core.orchestrate) — failure-isolated, and
    parallel across scopes when ``BENCH_JOBS>1``; each benchmark instance
    prints ``name,us_per_call,derived`` where ``derived`` is the scope's
    natural rate (GB/s, Mitems/s, modeled seconds, ...);
  * Figure 3 (ScopePlot line plot): regenerates the example saxpy plot
    from live results via the scopeplot spec pipeline;
  * §Roofline feed: the model scope surfaces the dry-run cells when
    results/dryrun exists.

Wall-clock numbers are CPU wall-clock on this container (framework
overhead + relative comparisons); TPU numbers are the modeled columns.

Env knobs: ``BENCH_JOBS`` (worker parallelism, default 1 → inline),
``BENCH_RESULTS_DIR`` (persist per-scope shards + merged.json).
"""
import os

SCOPES = ["example", "mxu", "comm", "nn", "instr", "histo", "linalg", "io",
          "model"]


def _derived(rec) -> str:
    for key, scale, unit in (("bytes_per_second", 1e-9, "GB/s"),
                             ("items_per_second", 1e-6, "Mitems/s"),
                             ("modeled_s", 1e6, "modeled_us"),
                             ("cells", 1, "cells")):
        v = rec.raw.get(key)
        if v:
            return f"{v * scale:.3f}{unit}"
    return ""


def _print_shard(shard) -> None:
    from repro.scopeplot import BenchmarkFile
    if shard.status != "ok" or shard.doc is None:
        first = shard.error.strip().splitlines()[-1] if shard.error else \
            shard.status
        print(f"{shard.scope}/SCOPE_FAILED,0.00,{first}")
        return
    bf = BenchmarkFile.from_dict(shard.doc)
    for rec in bf.without_errors():
        if rec.raw.get("run_type") == "aggregate":
            continue
        us = rec.real_time_seconds()
        us = us * 1e6 if us is not None else float("nan")
        print(f"{rec.name},{us:.2f},{_derived(rec)}")


def run_all(min_time: float = 0.02):
    """Run every scope through the orchestrator.

    Returns (RunResult, unavailable) where ``unavailable`` maps scopes
    that failed to import/register to their tracebacks — the orchestrator
    never schedules those, but the harness must still report them.
    """
    from repro.core import REGISTRY, RunOptions
    from repro.core.orchestrate import OrchestratorOptions, execute
    from repro.core.scope import ScopeManager

    jobs = int(os.environ.get("BENCH_JOBS", "1"))
    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load([f"repro.scopes.{s}_scope" for s in SCOPES])
    mgr.register_all()
    opts = OrchestratorOptions(
        jobs=jobs,
        run=RunOptions(min_time=min_time),
        results_dir=os.environ.get("BENCH_RESULTS_DIR"),
    )
    result = execute(mgr, REGISTRY, opts,
                     context_extra={"scopes": mgr.status()})
    unavailable = {s.scope.name: s.error for s in mgr.scopes()
                   if not s.available}
    return result, unavailable


def figure3_plot(docs) -> None:
    """Regenerate the paper's Fig. 3-style line plot via scopeplot."""
    import json
    import tempfile
    from repro.scopeplot.plot import render_spec
    ex = docs.get("example")
    if ex is None:
        return
    with tempfile.TemporaryDirectory() as d:
        src = os.path.join(d, "example.json")
        with open(src, "w") as f:
            json.dump(ex, f)
        spec = {
            "title": "saxpy throughput (Fig. 3 analogue)",
            "type": "line",
            "output": os.path.join("results", "fig3_saxpy.png"),
            "x_axis": {"label": "elements", "scale": "log"},
            "y_axis": {"label": "GB/s"},
            "series": [{"label": "saxpy", "input_file": src,
                        "regex": "example/saxpy", "xfield": "n",
                        "yfield": "bytes_per_second", "yscale": 1e-9}],
        }
        os.makedirs("results", exist_ok=True)
        out = render_spec(spec)
        print(f"fig3_plot,0.00,{out}")


def main() -> None:
    result, unavailable = run_all()
    docs = {}
    for scope in SCOPES:
        shard = result.shard(scope)
        if shard is None:
            err = unavailable.get(scope, "not scheduled")
            last = err.strip().splitlines()[-1] if err else "not scheduled"
            print(f"{scope}/SCOPE_FAILED,0.00,{last}")
            continue
        _print_shard(shard)
        if shard.status == "ok":
            docs[scope] = shard.doc
    figure3_plot(docs)


if __name__ == '__main__':
    main()
