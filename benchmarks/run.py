"""Benchmark harness — one section per paper table/figure.

  * Table IV (the scopes): every completed scope runs through the core
    runner; each benchmark instance prints ``name,us_per_call,derived``
    where ``derived`` is the scope's natural rate (GB/s, Mitems/s, modeled
    seconds, ...);
  * Figure 3 (ScopePlot line plot): regenerates the example saxpy plot
    from live results via the scopeplot spec pipeline;
  * §Roofline feed: the model scope surfaces the dry-run cells when
    results/dryrun exists.

Wall-clock numbers are CPU wall-clock on this container (framework
overhead + relative comparisons); TPU numbers are the modeled columns.
"""
import os

SCOPES = ["example", "mxu", "comm", "nn", "instr", "histo", "linalg", "io",
          "model"]


def _derived(rec) -> str:
    for key, scale, unit in (("bytes_per_second", 1e-9, "GB/s"),
                             ("items_per_second", 1e-6, "Mitems/s"),
                             ("modeled_s", 1e6, "modeled_us"),
                             ("cells", 1, "cells")):
        v = rec.raw.get(key)
        if v:
            return f"{v * scale:.3f}{unit}"
    return ""


def run_scope(scope: str, min_time: float = 0.02):
    from repro.core import REGISTRY, RunOptions, run_benchmarks
    from repro.core.scope import ScopeManager
    from repro.scopeplot import BenchmarkFile

    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load([f"repro.scopes.{scope}_scope"])
    mgr.register_all()
    benches = REGISTRY.filter(".*", scopes=[scope])
    doc = run_benchmarks(benches, RunOptions(min_time=min_time),
                         progress=False)
    bf = BenchmarkFile.from_dict(doc)
    for rec in bf.without_errors():
        if rec.raw.get("run_type") == "aggregate":
            continue
        us = rec.real_time_seconds()
        us = us * 1e6 if us is not None else float("nan")
        print(f"{rec.name},{us:.2f},{_derived(rec)}")
    return doc


def figure3_plot(docs) -> None:
    """Regenerate the paper's Fig. 3-style line plot via scopeplot."""
    import json
    import tempfile
    from repro.scopeplot.plot import render_spec
    ex = docs.get("example")
    if ex is None:
        return
    with tempfile.TemporaryDirectory() as d:
        src = os.path.join(d, "example.json")
        with open(src, "w") as f:
            json.dump(ex, f)
        spec = {
            "title": "saxpy throughput (Fig. 3 analogue)",
            "type": "line",
            "output": os.path.join("results", "fig3_saxpy.png"),
            "x_axis": {"label": "elements", "scale": "log"},
            "y_axis": {"label": "GB/s"},
            "series": [{"label": "saxpy", "input_file": src,
                        "regex": "example/saxpy", "xfield": "n",
                        "yfield": "bytes_per_second", "yscale": 1e-9}],
        }
        os.makedirs("results", exist_ok=True)
        out = render_spec(spec)
        print(f"fig3_plot,0.00,{out}")


def main() -> None:
    docs = {}
    for scope in SCOPES:
        try:
            docs[scope] = run_scope(scope)
        except Exception as e:  # noqa: BLE001 - isolate scope failures
            print(f"{scope}/SCOPE_FAILED,0.00,{type(e).__name__}:{e}")
    figure3_plot(docs)


if __name__ == '__main__':
    main()
