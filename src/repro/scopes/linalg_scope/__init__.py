"""LinAlg|Scope — linear-algebra operations (paper Table IV).

``batched_matmul`` sweeps a typed ``dtype`` axis (f32 vs bf16 einsum)
alongside the batch/size ints; the factorizations stay legacy int
sweeps but share the same measurement shape: operands + jitted op in a
fixture, the result declared with ``state.deliver`` so the wall meter
fences the pipelined batch before the clock stops.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry

NAME = "linalg"

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _register(registry: BenchmarkRegistry) -> None:
    def batched_matmul_setup(params):
        x = jnp.ones((params.b, params.n, params.n), _DTYPES[params.dtype])
        return jax.jit(lambda x: jnp.einsum("bij,bjk->bik", x, x)), x

    @benchmark(scope=NAME, registry=registry)
    def batched_matmul(state: State):
        """Batched einsum matmul; ``dtype`` selects the accumulation
        input precision."""
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(2 * state.params.b * state.params.n ** 3)
    batched_matmul.param_space(
        ParamSpace.product(dtype=["f32", "bf16"], b=[8], n=[128, 256]))
    batched_matmul.set_fixture(batched_matmul_setup)

    def matmul_rect_setup(params):
        from repro.kernels.matmul import matmul as pallas_matmul
        x = jnp.ones((params.m, params.k), jnp.float32)
        y = jnp.ones((params.k, params.n), jnp.float32)
        # blocks come from the tuned defaults (repro.kernels.tuning)
        return (lambda x, y: pallas_matmul(x, y)), x, y

    @benchmark(scope=NAME, registry=registry)
    def matmul_rect(state: State):
        """Rectangular matmul through the tiled Pallas kernel (interpret
        mode on CPU) — the non-square shape the MXU scope's square
        sweep never exercises."""
        fn, x, y = state.fixture
        while state.keep_running():
            state.deliver(fn(x, y))
        p = state.params
        state.counters["flops"] = 2.0 * p.m * p.n * p.k
    matmul_rect.param_space(m=[512], n=[256], k=[256])
    matmul_rect.set_fixture(matmul_rect_setup)
    # every block divides the m=512/n=256/k=256 instance's dims after
    # shape clamping; tuning this family refreshes the shared matmul
    # artifact from a rectangular workload
    matmul_rect.set_tunable("matmul", bm=[64, 128, 256, 512],
                            bn=[64, 128, 256], bk=[64, 128, 256])

    def cholesky_setup(params):
        return (jax.jit(jnp.linalg.cholesky),
                jnp.eye(params.n) * 4.0 + 0.1)

    @benchmark(scope=NAME, registry=registry)
    def cholesky(state: State):
        fn, a = state.fixture
        while state.keep_running():
            state.deliver(fn(a))
        # ~n^3/3 fused multiply-adds for a dense Cholesky factorization
        state.counters["flops"] = state.params.n ** 3 / 3.0
    cholesky.args([256]).args([512]).set_arg_names(["n"])
    cholesky.set_fixture(cholesky_setup)

    def triangular_solve_setup(params):
        n = params.n
        a = jnp.eye(n) + jnp.tril(jnp.ones((n, n)) * 0.01)
        b = jnp.ones((n, 16))
        fn = jax.jit(lambda a, b: jax.scipy.linalg.solve_triangular(
            a, b, lower=True))
        return fn, a, b

    @benchmark(scope=NAME, registry=registry)
    def triangular_solve(state: State):
        fn, a, b = state.fixture
        while state.keep_running():
            state.deliver(fn(a, b))
        # n^2 multiply-adds per right-hand side, 16 rhs columns
        state.counters["flops"] = state.params.n ** 2 * 16.0
    triangular_solve.args([256]).set_arg_names(["n"])
    triangular_solve.set_fixture(triangular_solve_setup)


SCOPE = Scope(name=NAME, version="2.0.0",
              description="linear algebra operations", register=_register)
