"""LinAlg|Scope — linear-algebra operations (paper Table IV)."""
import jax
import jax.numpy as jnp

from repro.core import Scope, State, benchmark, sync
from repro.core.registry import BenchmarkRegistry

NAME = "linalg"


def _register(registry: BenchmarkRegistry) -> None:
    @benchmark(scope=NAME, registry=registry)
    def batched_matmul(state: State):
        b, n = state.range(0), state.range(1)
        x = jnp.ones((b, n, n), jnp.float32)
        fn = jax.jit(lambda x: jnp.einsum("bij,bjk->bik", x, x))
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_items_processed(2 * b * n ** 3)
    batched_matmul.args_product([[8], [128, 256]])
    batched_matmul.set_arg_names(["b", "n"])

    @benchmark(scope=NAME, registry=registry)
    def cholesky(state: State):
        n = state.range(0)
        a = jnp.eye(n) * 4.0 + 0.1
        fn = jax.jit(jnp.linalg.cholesky)
        sync(fn(a))
        while state.keep_running():
            sync(fn(a))
    cholesky.args([256]).args([512]).set_arg_names(["n"])

    @benchmark(scope=NAME, registry=registry)
    def triangular_solve(state: State):
        n = state.range(0)
        a = jnp.eye(n) + jnp.tril(jnp.ones((n, n)) * 0.01)
        b = jnp.ones((n, 16))
        fn = jax.jit(lambda a, b: jax.scipy.linalg.solve_triangular(
            a, b, lower=True))
        sync(fn(a, b))
        while state.keep_running():
            sync(fn(a, b))
    triangular_solve.args([256]).set_arg_names(["n"])


SCOPE = Scope(name=NAME, version="1.0.0",
              description="linear algebra operations", register=_register)
