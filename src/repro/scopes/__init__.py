"""Benchmark scopes — paper §IV (Table IV analogue).

Each subpackage is an isolated benchmark group exporting ``SCOPE``.
Scopes never import each other; shared utilities come from ``repro.core``.
"""
