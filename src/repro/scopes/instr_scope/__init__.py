"""Instr|Scope — instruction/op latencies and throughput.

Elementwise transcendentals, reductions, dtype conversions at fixed array
size: the per-op cost floor that model-level numbers decompose into.
One ``elementwise`` family sweeps a typed ``op`` axis instead of seven
generated per-op family clones; the fixture builds the input array and
the jitted op untimed, so the warm phase isolates trace+compile into
``compile_time_s``.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark, sync
from repro.core.registry import BenchmarkRegistry

NAME = "instr"

_OPS = {
    "exp": jnp.exp,
    "tanh": jnp.tanh,
    "rsqrt": jax.lax.rsqrt,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "add": lambda x: x + x,
    "mul": lambda x: x * x,
}


def _register(registry: BenchmarkRegistry) -> None:
    def elementwise_setup(params):
        x = jnp.linspace(0.1, 1.0, params.n, dtype=jnp.float32)
        return jax.jit(_OPS[params.op]), x

    @benchmark(scope=NAME, registry=registry)
    def elementwise(state: State):
        """Elementwise op throughput; the ``op`` axis selects the
        primitive."""
        fn, x = state.fixture
        while state.keep_running():
            sync(fn(x))
        state.set_items_processed(state.params.n)
        state.set_bytes_processed(8 * state.params.n)
    elementwise.param_space(
        ParamSpace.product(op=list(_OPS), n=[1 << 20]))
    elementwise.set_fixture(elementwise_setup)

    @benchmark(scope=NAME, registry=registry)
    def reduce_sum(state: State):
        n = state.range(0)
        x = jnp.ones((n,), jnp.float32)
        fn = jax.jit(jnp.sum)
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_bytes_processed(4 * n)
    reduce_sum.args([1 << 20]).set_arg_names(["n"])

    @benchmark(scope=NAME, registry=registry)
    def convert_f32_bf16(state: State):
        n = state.range(0)
        x = jnp.ones((n,), jnp.float32)
        fn = jax.jit(lambda x: x.astype(jnp.bfloat16))
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_bytes_processed(6 * n)
    convert_f32_bf16.args([1 << 20]).set_arg_names(["n"])


SCOPE = Scope(name=NAME, version="2.0.0",
              description="per-op latencies/throughput", register=_register)
