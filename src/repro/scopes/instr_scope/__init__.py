"""Instr|Scope — instruction/op latencies and throughput.

Elementwise transcendentals, reductions, dtype conversions at fixed array
size: the per-op cost floor that model-level numbers decompose into.
One ``elementwise`` family sweeps a typed ``op`` axis instead of seven
generated per-op family clones; every family builds its operand array
and jitted op in a fixture (untimed — the warm phase isolates
trace+compile into ``compile_time_s``) and declares its output as the
sync deliverable, so the wall meter fences the pipelined batch once
instead of the body blocking every iteration.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry

NAME = "instr"

_OPS = {
    "exp": jnp.exp,
    "tanh": jnp.tanh,
    "rsqrt": jax.lax.rsqrt,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "add": lambda x: x + x,
    "mul": lambda x: x * x,
}


def _register(registry: BenchmarkRegistry) -> None:
    def elementwise_setup(params):
        x = jnp.linspace(0.1, 1.0, params.n, dtype=jnp.float32)
        return jax.jit(_OPS[params.op]), x

    @benchmark(scope=NAME, registry=registry)
    def elementwise(state: State):
        """Elementwise op throughput; the ``op`` axis selects the
        primitive."""
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(state.params.n)
        state.set_bytes_processed(8 * state.params.n)
    elementwise.param_space(
        ParamSpace.product(op=list(_OPS), n=[1 << 20]))
    elementwise.set_fixture(elementwise_setup)

    def reduce_sum_setup(params):
        return jax.jit(jnp.sum), jnp.ones((params.n,), jnp.float32)

    @benchmark(scope=NAME, registry=registry)
    def reduce_sum(state: State):
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_bytes_processed(4 * state.params.n)
    reduce_sum.args([1 << 20]).set_arg_names(["n"])
    reduce_sum.set_fixture(reduce_sum_setup)

    def convert_setup(params):
        return (jax.jit(lambda x: x.astype(jnp.bfloat16)),
                jnp.ones((params.n,), jnp.float32))

    @benchmark(scope=NAME, registry=registry)
    def convert_f32_bf16(state: State):
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_bytes_processed(6 * state.params.n)
    convert_f32_bf16.args([1 << 20]).set_arg_names(["n"])
    convert_f32_bf16.set_fixture(convert_setup)


SCOPE = Scope(name=NAME, version="2.0.0",
              description="per-op latencies/throughput", register=_register)
