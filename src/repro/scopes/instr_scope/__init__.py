"""Instr|Scope — instruction/op latencies and throughput.

Elementwise transcendentals, reductions, dtype conversions at fixed array
size: the per-op cost floor that model-level numbers decompose into.
"""
import jax
import jax.numpy as jnp

from repro.core import Scope, State, benchmark, sync
from repro.core.registry import BenchmarkRegistry

NAME = "instr"

_OPS = {
    "exp": jnp.exp,
    "tanh": jnp.tanh,
    "rsqrt": jax.lax.rsqrt,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "add": lambda x: x + x,
    "mul": lambda x: x * x,
}


def _register(registry: BenchmarkRegistry) -> None:
    for opname, op in _OPS.items():
        def make(op=op, opname=opname):
            def bench(state: State):
                n = state.range(0)
                x = jnp.linspace(0.1, 1.0, n, dtype=jnp.float32)
                fn = jax.jit(op)
                sync(fn(x))
                while state.keep_running():
                    sync(fn(x))
                state.set_items_processed(n)
                state.set_bytes_processed(8 * n)
            bench.__name__ = opname
            bench.__doc__ = f"elementwise {opname} throughput"
            return bench
        b = benchmark(scope=NAME, registry=registry)(make())
        b.args([1 << 20]).set_arg_names(["n"])

    @benchmark(scope=NAME, registry=registry)
    def reduce_sum(state: State):
        n = state.range(0)
        x = jnp.ones((n,), jnp.float32)
        fn = jax.jit(jnp.sum)
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_bytes_processed(4 * n)
    reduce_sum.args([1 << 20]).set_arg_names(["n"])

    @benchmark(scope=NAME, registry=registry)
    def convert_f32_bf16(state: State):
        n = state.range(0)
        x = jnp.ones((n,), jnp.float32)
        fn = jax.jit(lambda x: x.astype(jnp.bfloat16))
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_bytes_processed(6 * n)
    convert_f32_bf16.args([1 << 20]).set_arg_names(["n"])


SCOPE = Scope(name=NAME, version="1.0.0",
              description="per-op latencies/throughput", register=_register)
