"""I/O|Scope — disk I/O operations (paper Table IV): checkpoint +
data-pipeline throughput of the production substrates.  The checkpoint
save/restore family clones are one typed ``checkpoint`` family with an
``op`` axis.  Both families complete their work on the host inside the
timed loop, so they declare a no-op sync fence (``set_sync``) instead
of deliverables — there is no async dispatch to wait for."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry

NAME = "io"


def _register(registry: BenchmarkRegistry) -> None:
    @benchmark(scope=NAME, registry=registry)
    def checkpoint(state: State):
        """Sharded-checkpoint save/restore throughput (repro.checkpoint);
        the ``op`` axis selects the direction."""
        from repro.checkpoint import load_checkpoint, save_checkpoint
        mb = state.params.MiB
        tree = {"w": jnp.ones((mb * 1024 * 256,), jnp.float32)}
        with tempfile.TemporaryDirectory() as d:
            if state.params.op == "save":
                i = 0
                while state.keep_running():
                    save_checkpoint(os.path.join(d, f"ck{i}"), tree, step=i)
                    i += 1
            else:
                path = save_checkpoint(os.path.join(d, "ck"), tree, step=0)
                while state.keep_running():
                    load_checkpoint(path, tree)
        state.set_bytes_processed(mb * 1024 * 1024)
    checkpoint.param_space(
        ParamSpace.product(op=["save", "restore"], MiB=[4, 32]))
    checkpoint.set_sync(lambda ctx: None)      # host-synchronous

    @benchmark(scope=NAME, registry=registry)
    def data_pipeline(state: State):
        """Synthetic-LM pipeline batches/s (repro.data, no prefetch)."""
        from repro.data import DataConfig, SyntheticLM
        seq = state.range(0)
        src = SyntheticLM(DataConfig(vocab_size=32000, seq_len=seq,
                                     global_batch=8))
        i = 0
        while state.keep_running():
            src.batch(i)
            i += 1
        state.set_items_processed(8 * seq)
    data_pipeline.args([512]).args([2048]).set_arg_names(["seq"])
    data_pipeline.set_sync(lambda ctx: None)   # host-synchronous


SCOPE = Scope(name=NAME, version="2.0.0",
              description="checkpoint + data-pipeline I/O",
              register=_register)
