"""NN|Scope — the cuDNN|Scope analogue: neural-network op hot-spots.

Layer-level bodies straight from the production model code: flash
attention (XLA custom-VJP formulation), RMSNorm (one typed family,
``backend`` axis selecting XLA vs Pallas), MoE dispatch (scatter
path), and the Mamba2 SSD chunk scan.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark, sync
from repro.core.registry import BenchmarkRegistry

NAME = "nn"


def _register(registry: BenchmarkRegistry) -> None:
    from repro.models import layers as L

    @benchmark(scope=NAME, registry=registry)
    def flash_attention_fwd(state: State):
        """Causal flash attention forward (B=2, H=4, D=64) vs seq len."""
        S = state.range(0)
        q = jnp.ones((2, S, 4, 64), jnp.float32)
        k = jnp.ones((2, S, 2, 64), jnp.float32)
        v = jnp.ones((2, S, 2, 64), jnp.float32)
        fn = jax.jit(lambda q, k, v: L.flash_attention_xla(
            q, k, v, causal=True, chunk_q=128, chunk_k=128))
        sync(fn(q, k, v))
        while state.keep_running():
            sync(fn(q, k, v))
        state.counters["attn_flops"] = 4.0 * 2 * 4 * S * S * 64 / 2
    flash_attention_fwd.args([256]).args([512]).args([1024])
    flash_attention_fwd.set_arg_names(["seq"])

    @benchmark(scope=NAME, registry=registry)
    def flash_attention_bwd(state: State):
        """Flash attention fwd+bwd through the custom VJP."""
        S = state.range(0)
        q = jnp.ones((2, S, 4, 64), jnp.float32)
        k = jnp.ones((2, S, 2, 64), jnp.float32)
        v = jnp.ones((2, S, 2, 64), jnp.float32)
        fn = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
            L.flash_attention_xla(q, k, v, chunk_q=128, chunk_k=128) ** 2),
            argnums=(0, 1, 2)))
        sync(fn(q, k, v))
        while state.keep_running():
            sync(fn(q, k, v))
    flash_attention_bwd.args([256]).args([512]).set_arg_names(["seq"])

    def rmsnorm_setup(params):
        x = jnp.ones((params.rows, params.d), jnp.float32)
        s = jnp.ones((params.d,), jnp.float32)
        if params.backend == "xla":
            p = {"scale": s}
            return jax.jit(lambda x: L.rms_norm(p, x)), x
        from repro.kernels.rmsnorm import rmsnorm
        return (lambda x: rmsnorm(x, s, br=128)), x

    @benchmark(scope=NAME, registry=registry)
    def rmsnorm(state: State):
        """RMSNorm through the selected backend (XLA vs Pallas) — one
        family, not a per-backend clone."""
        fn, x = state.fixture
        while state.keep_running():
            sync(fn(x))
        state.set_bytes_processed(2 * 4 * state.params.rows * state.params.d)
    rmsnorm.param_space(
        ParamSpace.product(backend=["xla"], rows=[4096], d=[1024, 4096])
        + ParamSpace.cases({"backend": "pallas", "rows": 1024, "d": 1024}))
    rmsnorm.set_fixture(rmsnorm_setup)

    @benchmark(scope=NAME, registry=registry)
    def moe_dispatch_scatter(state: State):
        """Capacity-based MoE (router+dispatch+experts+combine)."""
        E, k, d, ff = 8, 2, 256, 512
        T = state.range(0)
        p = L.init_moe(jax.random.PRNGKey(0), d, E, ff, 0)
        x = jnp.ones((1, T, d), jnp.float32)
        fn = jax.jit(lambda x: L.moe_scatter(p, x, top_k=k,
                                             capacity_factor=1.25)[0])
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_items_processed(T)
    moe_dispatch_scatter.args([1024]).args([4096])
    moe_dispatch_scatter.set_arg_names(["tokens"])

    @benchmark(scope=NAME, registry=registry)
    def ssd_chunked_scan(state: State):
        """Mamba2 SSD chunked scan (XLA formulation)."""
        S = state.range(0)
        b, h, p_, n = 2, 4, 64, 64
        x = jnp.ones((b, S, h, p_), jnp.float32) * 0.1
        dt = jnp.ones((b, S, h), jnp.float32) * 0.1
        A = -jnp.ones((h,), jnp.float32)
        Bm = jnp.ones((b, S, 1, n), jnp.float32) * 0.1
        Cm = jnp.ones((b, S, 1, n), jnp.float32) * 0.1
        D = jnp.ones((h,), jnp.float32)
        fn = jax.jit(lambda *a: L.ssd_chunked(*a, chunk=128)[0])
        sync(fn(x, dt, A, Bm, Cm, D))
        while state.keep_running():
            sync(fn(x, dt, A, Bm, Cm, D))
        state.set_items_processed(b * S)
    ssd_chunked_scan.args([1024]).args([4096]).set_arg_names(["seq"])


SCOPE = Scope(name=NAME, version="2.0.0",
              description="NN-operation hot-spots (cuDNN|Scope analogue)",
              register=_register)
