"""NN|Scope — the cuDNN|Scope analogue: neural-network op hot-spots.

Layer-level bodies straight from the production model code: flash
attention (XLA custom-VJP formulation), RMSNorm (one typed family,
``backend`` axis selecting XLA vs Pallas), MoE dispatch (scatter
path), and the Mamba2 SSD chunk scan.  Every family builds operands +
jitted callable in a fixture (untimed; the runner's warm phase reports
trace+compile as ``compile_time_s``) and declares its output with
``state.deliver`` — the wall meter fences the pipelined batch once
before the clock stops instead of the body blocking every iteration.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry

NAME = "nn"


def _attn_operands(S):
    q = jnp.ones((2, S, 4, 64), jnp.float32)
    k = jnp.ones((2, S, 2, 64), jnp.float32)
    v = jnp.ones((2, S, 2, 64), jnp.float32)
    return q, k, v


def _register(registry: BenchmarkRegistry) -> None:
    from repro.models import layers as L

    def flash_fwd_setup(params):
        fn = jax.jit(lambda q, k, v: L.flash_attention_xla(
            q, k, v, causal=True, chunk_q=128, chunk_k=128))
        return (fn,) + _attn_operands(params.seq)

    @benchmark(scope=NAME, registry=registry)
    def flash_attention_fwd(state: State):
        """Causal flash attention forward (B=2, H=4, D=64) vs seq len."""
        fn, q, k, v = state.fixture
        while state.keep_running():
            state.deliver(fn(q, k, v))
        S = state.params.seq
        state.counters["attn_flops"] = 4.0 * 2 * 4 * S * S * 64 / 2
    flash_attention_fwd.args([256]).args([512]).args([1024])
    flash_attention_fwd.set_arg_names(["seq"])
    flash_attention_fwd.set_fixture(flash_fwd_setup)

    def flash_bwd_setup(params):
        fn = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
            L.flash_attention_xla(q, k, v, chunk_q=128, chunk_k=128) ** 2),
            argnums=(0, 1, 2)))
        return (fn,) + _attn_operands(params.seq)

    @benchmark(scope=NAME, registry=registry)
    def flash_attention_bwd(state: State):
        """Flash attention fwd+bwd through the custom VJP."""
        fn, q, k, v = state.fixture
        while state.keep_running():
            state.deliver(fn(q, k, v))
        S = state.params.seq
        # fwd + recompute + bwd ~ 2.5x the forward attention flops
        state.counters["attn_flops"] = 2.5 * 4.0 * 2 * 4 * S * S * 64 / 2
    flash_attention_bwd.args([256]).args([512]).set_arg_names(["seq"])
    flash_attention_bwd.set_fixture(flash_bwd_setup)

    def rmsnorm_setup(params):
        x = jnp.ones((params.rows, params.d), jnp.float32)
        s = jnp.ones((params.d,), jnp.float32)
        if params.backend == "xla":
            p = {"scale": s}
            return jax.jit(lambda x: L.rms_norm(p, x)), x
        from repro.kernels.rmsnorm import rmsnorm
        # row-block size comes from the tuned defaults
        # (repro.kernels.tuning: tuned.json, env, or builtin)
        return (lambda x: rmsnorm(x, s)), x

    @benchmark(scope=NAME, registry=registry)
    def rmsnorm(state: State):
        """RMSNorm through the selected backend (XLA vs Pallas) — one
        family, not a per-backend clone."""
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_bytes_processed(2 * 4 * state.params.rows * state.params.d)
    rmsnorm.param_space(
        ParamSpace.product(backend=["xla"], rows=[4096], d=[1024, 4096])
        + ParamSpace.cases({"backend": "pallas", "rows": 1024, "d": 1024}))
    rmsnorm.set_fixture(rmsnorm_setup)
    # every br divides the pallas instance's rows=1024
    rmsnorm.set_tunable("rmsnorm", br=[64, 128, 256, 512, 1024],
                        instance={"backend": "pallas"})

    def flash_pallas_setup(params):
        from repro.kernels.flash_attention import flash_attention
        # bq/bk come from the tuned defaults (repro.kernels.tuning).
        # Shape is deliberately small (B=2, H=2, K=1, D=32): interpret
        # mode executes the kernel body in Python, and the full
        # _attn_operands shape takes minutes per call on CPU.
        fn = lambda q, k, v: flash_attention(q, k, v, causal=True)  # noqa: E731
        S = params.seq
        q = jnp.ones((2, S, 2, 32), jnp.float32)
        k = jnp.ones((2, S, 1, 32), jnp.float32)
        v = jnp.ones((2, S, 1, 32), jnp.float32)
        return fn, q, k, v

    @benchmark(scope=NAME, registry=registry)
    def flash_attention_pallas(state: State):
        """Causal flash attention through the Pallas kernel (interpret
        mode on CPU; tuned bq/bk blocks)."""
        fn, q, k, v = state.fixture
        while state.keep_running():
            state.deliver(fn(q, k, v))
        S = state.params.seq
        state.counters["attn_flops"] = 4.0 * 2 * 2 * S * S * 32 / 2
    flash_attention_pallas.param_space(seq=[128])
    flash_attention_pallas.set_fixture(flash_pallas_setup)
    # every bq/bk divides the seq=128 instance's sequence length
    flash_attention_pallas.set_tunable("flash_attention",
                                       bq=[32, 64, 128],
                                       bk=[32, 64, 128])

    def ssd_pallas_setup(params):
        from repro.kernels.ssd_scan import ssd
        S = params.seq
        b, h, p_, n = 2, 4, 64, 64
        x = jnp.ones((b, S, h, p_), jnp.float32) * 0.1
        dt = jnp.ones((b, S, h), jnp.float32) * 0.1
        A = -jnp.ones((h,), jnp.float32)
        Bm = jnp.ones((b, S, 1, n), jnp.float32) * 0.1
        Cm = jnp.ones((b, S, 1, n), jnp.float32) * 0.1
        D = jnp.ones((h,), jnp.float32)
        # chunk comes from the tuned defaults (repro.kernels.tuning)
        fn = lambda *a: ssd(*a)[0]  # noqa: E731
        return fn, x, dt, A, Bm, Cm, D

    @benchmark(scope=NAME, registry=registry)
    def ssd_scan_pallas(state: State):
        """Mamba2 SSD scan through the Pallas chunk kernel (interpret
        mode on CPU; tuned chunk length)."""
        fn, *operands = state.fixture
        while state.keep_running():
            state.deliver(fn(*operands))
        state.set_items_processed(2 * state.params.seq)
    ssd_scan_pallas.param_space(seq=[512])
    ssd_scan_pallas.set_fixture(ssd_pallas_setup)
    # every chunk divides the seq=512 instance's sequence length
    ssd_scan_pallas.set_tunable("ssd_scan", chunk=[64, 128, 256, 512])

    def moe_setup(params):
        E, k, d, ff = 8, 2, 256, 512
        p = L.init_moe(jax.random.PRNGKey(0), d, E, ff, 0)
        x = jnp.ones((1, params.tokens, d), jnp.float32)
        fn = jax.jit(lambda x: L.moe_scatter(p, x, top_k=k,
                                             capacity_factor=1.25)[0])
        return fn, x

    @benchmark(scope=NAME, registry=registry)
    def moe_dispatch_scatter(state: State):
        """Capacity-based MoE (router+dispatch+experts+combine)."""
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(state.params.tokens)
    moe_dispatch_scatter.args([1024]).args([4096])
    moe_dispatch_scatter.set_arg_names(["tokens"])
    moe_dispatch_scatter.set_fixture(moe_setup)

    def ssd_setup(params):
        S = params.seq
        b, h, p_, n = 2, 4, 64, 64
        x = jnp.ones((b, S, h, p_), jnp.float32) * 0.1
        dt = jnp.ones((b, S, h), jnp.float32) * 0.1
        A = -jnp.ones((h,), jnp.float32)
        Bm = jnp.ones((b, S, 1, n), jnp.float32) * 0.1
        Cm = jnp.ones((b, S, 1, n), jnp.float32) * 0.1
        D = jnp.ones((h,), jnp.float32)
        fn = jax.jit(lambda *a: L.ssd_chunked(*a, chunk=128)[0])
        return fn, x, dt, A, Bm, Cm, D

    @benchmark(scope=NAME, registry=registry)
    def ssd_chunked_scan(state: State):
        """Mamba2 SSD chunked scan (XLA formulation)."""
        fn, *operands = state.fixture
        while state.keep_running():
            state.deliver(fn(*operands))
        state.set_items_processed(2 * state.params.seq)
    ssd_chunked_scan.args([1024]).args([4096]).set_arg_names(["seq"])
    ssd_chunked_scan.set_fixture(ssd_setup)


SCOPE = Scope(name=NAME, version="2.0.0",
              description="NN-operation hot-spots (cuDNN|Scope analogue)",
              register=_register)
