"""Example|Scope — the template scope (paper §IV-C).

Demonstrates every required or suggested structure for a new scope:

  1. a ``SCOPE`` export (the CMakeLists.txt/object-library analogue) —
     *required*;
  2. benchmark bodies registered through the core benchmark library —
     *required*;
  3. two new command-line flags (``--example.exit_code`` and
     ``--example.greet``), declared clara::Opts-style — *optional*;
  4. an init hook that makes the binary exit during initialization when
     ``--example.exit_code`` is given (exactly what the paper's
     Example|Scope does) — *optional*;
  5. per-benchmark documentation in docstrings — *optional*;
  6. a typed parameter space with a fixture (``axpy``): a ``dtype``
     axis instead of per-dtype family clones, with array allocation in
     ``setup(params)`` so it never pollutes the timed region —
     *recommended for new benchmarks*;
  7. sync deliverables (``state.deliver(out)``): the body declares its
     output so the measurement layer can fence async dispatch before
     the clock stops (docs/measurement.md) — on a host-numpy scope the
     fence is a no-op, but declaring the deliverable keeps the body
     correct under any backend — *recommended for new benchmarks*.
"""
from repro.core import FLAGS, ParamSpace, Scope, State, benchmark
from repro.core.flags import FlagRegistry
from repro.core.registry import BenchmarkRegistry

import numpy as np

NAME = "example"


def _declare_flags(flags: FlagRegistry) -> None:
    flags.declare(f"{NAME}/exit_code", owner=NAME, type=int, default=None,
                  help="exit immediately with this status (demo of init "
                       "hooks aborting the binary)")
    flags.declare(f"{NAME}/greet", owner=NAME, default=None,
                  help="print a greeting during post-parse init")


def _post_parse():
    code = FLAGS.get(f"{NAME}/exit_code")
    if code is not None:
        return int(code)
    greet = FLAGS.get(f"{NAME}/greet")
    if greet:
        print(f"example scope says: {greet}")
    return None


def _register(registry: BenchmarkRegistry) -> None:
    @benchmark(scope=NAME, registry=registry)
    def noop(state: State):
        """Measures benchmark-harness overhead: an empty timed body."""
        while state.keep_running():
            pass
        state.set_items_processed(1)
    # nothing is dispatched, so there is nothing to fence
    noop.set_sync(lambda ctx: None)

    @benchmark(scope=NAME, registry=registry)
    def saxpy(state: State):
        """Single-precision a*x+y on the host — the classic demo kernel."""
        n = state.range(0)
        x = np.ones(n, np.float32)
        y = np.ones(n, np.float32)
        while state.keep_running():
            y = 2.0 * x + y
        state.set_bytes_processed(3 * 4 * n)
        state.set_items_processed(n)
    saxpy.range_multiplier_args(1 << 8, 1 << 16, mult=4)
    saxpy.set_arg_names(["n"])
    # host numpy is synchronous; declare that instead of leaving the
    # family unfenced
    saxpy.set_sync(lambda ctx: None)

    _DTYPES = {"f32": np.float32, "f64": np.float64}

    def axpy_setup(params):
        dt = _DTYPES[params.dtype]
        return np.ones(params.n, dt), np.ones(params.n, dt)

    @benchmark(scope=NAME, registry=registry)
    def axpy(state: State):
        """Typed-axis a*x+y: ``dtype`` is a named axis (no per-dtype
        family clones), the arrays come from the fixture (untimed), and
        the result is the declared sync deliverable."""
        x, y = state.fixture
        while state.keep_running():
            y = state.deliver(2.0 * x + y)
        itemsize = x.dtype.itemsize
        state.set_bytes_processed(3 * itemsize * state.params.n)
        state.set_items_processed(state.params.n)
    axpy.param_space(ParamSpace.product(dtype=list(_DTYPES), n=[1 << 14]))
    axpy.set_fixture(axpy_setup)


SCOPE = Scope(
    name=NAME,
    version="1.0.0",
    description="Template scope demonstrating the integration surface.",
    register=_register,
    declare_flags=_declare_flags,
    post_parse=_post_parse,
)
