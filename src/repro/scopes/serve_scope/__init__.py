"""Serve|Scope — tail latency of the serving engine under open-loop load.

Drives :class:`repro.serve.ServeEngine` (slot-based continuous
batching) with **open-loop** arrival traces from
:mod:`repro.core.arrivals`: requests arrive on a seeded schedule that
does not slow down when the server does, so queueing under overload is
actually exercised — the regime where p99/p999 and goodput against an
SLO carry information (closed-loop drivers hide exactly this).

The parameter space crosses the load shape with the engine
configuration:

  * ``arrival`` — poisson | bursty | diurnal (the generator kind);
  * ``rate``    — mean offered load in requests/second;
  * ``max_batch`` — the engine's slot-pool size (admission capacity);
  * ``mix``     — prompt-length mix: ``short`` (uniform tiny prompts)
    or ``mixed`` (alternating short/long, stressing prefill buckets
    and head-of-line effects).

The body paces submissions with ``State.now()`` (the sanctioned clock
for *scheduling*, not timing), stamps each request with its scheduled
arrival instant so latency includes queueing, and delivers one
``state.observe(...)`` sample per completed request (``ttft_s``,
``latency_s``) plus one per engine step (``queue_depth``).  Run with
``--meters wall,cpu,latency [--slo-ms N]`` to turn those samples into
``latency_p50_s``…``latency_p999_s``, ``ttft_p50_s``/``ttft_p99_s``,
``queue_depth_mean`` and ``goodput_rps`` counters on every record
(docs/serving.md).
"""
import numpy as np

from repro.core import FLAGS, ParamSpace, Scope, State, benchmark
from repro.core.arrivals import ARRIVAL_KINDS, generate
from repro.core.registry import BenchmarkRegistry

NAME = "serve"

#: Prompt-length mixes (token counts, cycled over the request count).
#: ``mixed`` alternates across prefill buckets so admissions compile and
#: exercise more than one prefill program.
_MIXES = {"short": (4,), "mixed": (4, 24)}


def _declare_flags(flags) -> None:
    flags.declare(f"{NAME}/requests", owner=NAME, type=int, default=12,
                  help="requests per measured batch (the trace length)")
    flags.declare(f"{NAME}/tokens", owner=NAME, type=int, default=8,
                  help="tokens decoded per request")
    flags.declare(f"{NAME}/seed", owner=NAME, type=int, default=0,
                  help="seed for the arrival trace and prompt contents "
                       "(same seed → byte-identical trace everywhere)")


def _register(registry: BenchmarkRegistry) -> None:
    import jax

    from repro.models import build, get_config
    from repro.serve import ServeConfig, ServeEngine

    def under_load_setup(params):
        """Tiny decoder + engine + a seeded arrival trace, all untimed."""
        cfg = get_config("llama3.2-1b").reduced().override(
            num_layers=2, vocab_size=128)
        api = build(cfg)
        weights = api.init(jax.random.PRNGKey(0))
        n = int(FLAGS.get(f"{NAME}/requests", 12))
        seed = int(FLAGS.get(f"{NAME}/seed", 0))
        lens = _MIXES[params.mix]
        rng = np.random.RandomState(seed)
        prompts = [rng.randint(1, cfg.vocab_size,
                               size=lens[i % len(lens)]).astype(np.int32)
                   for i in range(n)]
        offsets = generate(params.arrival, params.rate, n, seed)
        engine = ServeEngine(api, weights, ServeConfig(
            max_batch=params.max_batch, max_len=128,
            prompt_buckets=(16, 32)))
        return engine, prompts, offsets

    @benchmark(scope=NAME, registry=registry)
    def under_load(state: State):
        """Open-loop serving: replay the instance's seeded arrival trace
        through the engine and observe per-request TTFT/latency and
        per-step queue depth.  The engine forces every decoded token to
        the host each step (fenced timestamps), so the family is
        host-synchronous — the no-op sync fence is correct, and the
        latency samples are delivery-timed by construction."""
        engine, prompts, offsets = state.fixture
        max_tokens = int(FLAGS.get(f"{NAME}/tokens", 8))
        while state.keep_running():
            t0 = State.now()
            idx = 0
            while (idx < len(prompts) or engine.queue
                   or any(s is not None for s in engine.slots)):
                now = State.now() - t0
                while idx < len(prompts) and offsets[idx] <= now:
                    engine.submit(prompts[idx], max_tokens=max_tokens,
                                  submitted_at=t0 + offsets[idx])
                    idx += 1
                if not (engine.queue
                        or any(s is not None for s in engine.slots)):
                    continue          # idle: spin until the next arrival
                for req in engine.step():
                    state.observe({
                        "latency_s": req.done_at - req.submitted_at,
                        "ttft_s": req.first_token_at - req.submitted_at,
                    })
                state.observe({"queue_depth": engine.queue_depth_log[-1]})
        state.set_items_processed(len(prompts))
    under_load.param_space(ParamSpace.product(
        arrival=list(ARRIVAL_KINDS), rate=[32.0], max_batch=[4],
        mix=list(_MIXES)))
    under_load.set_fixture(under_load_setup)
    # every step round-trips tokens to the host: host-synchronous
    under_load.set_sync(lambda ctx: None)
    # one trace replay per batch — the trace *is* the workload; wall
    # time is dominated by the arrival horizon, not iteration count
    under_load.set_iterations(1)


SCOPE = Scope(name=NAME, version="1.0.0",
              description="tail latency of the serving engine under "
                          "open-loop load (docs/serving.md)",
              register=_register, declare_flags=_declare_flags)
