"""Histo|Scope — GPU histogramming (paper Table IV), TPU-adapted.

One ``histogram`` family with a typed ``backend`` axis compares
jnp.bincount (XLA scatter-add) against the Pallas one-hot-matmul kernel
(repro.kernels.histogram) across input sizes and bin counts — the
per-backend family clones collapsed into a single parameter space.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry

NAME = "histo"


def _register(registry: BenchmarkRegistry) -> None:
    def histogram_setup(params):
        x = jax.random.randint(jax.random.PRNGKey(0), (params.n,), 0,
                               params.bins)
        if params.backend == "xla":
            bins = params.bins
            return jax.jit(lambda x: jnp.bincount(x, length=bins)), x
        from repro.kernels.histogram import histogram as pallas_hist
        return (lambda x: pallas_hist(x, params.bins, chunk=4096)), x

    @benchmark(scope=NAME, registry=registry)
    def histogram(state: State):
        """Histogramming through the selected backend (XLA scatter vs
        Pallas one-hot matmul); the counts are the sync deliverable."""
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(state.params.n)

    # pallas (interpret mode on CPU) stays one small point; the XLA path
    # sweeps the full size × bins grid
    histogram.param_space(
        ParamSpace.product(backend=["xla", "pallas"],
                           n=[1 << 16, 1 << 20],
                           bins=[256, 4096])
        .where(lambda p: p.backend == "xla"
               or (p.n == 1 << 16 and p.bins == 256)))
    histogram.set_fixture(histogram_setup)


SCOPE = Scope(name=NAME, version="2.0.0",
              description="histogramming: XLA scatter vs Pallas one-hot",
              register=_register)
