"""Histo|Scope — GPU histogramming (paper Table IV), TPU-adapted.

Compares jnp.bincount (XLA scatter-add) against the Pallas one-hot-matmul
kernel (repro.kernels.histogram) across input sizes and bin counts.
"""
import jax
import jax.numpy as jnp

from repro.core import Scope, State, benchmark, sync
from repro.core.registry import BenchmarkRegistry

NAME = "histo"


def _register(registry: BenchmarkRegistry) -> None:
    @benchmark(scope=NAME, registry=registry)
    def bincount_xla(state: State):
        n, bins = state.range(0), state.range(1)
        x = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, bins)
        fn = jax.jit(lambda x: jnp.bincount(x, length=bins))
        sync(fn(x))
        while state.keep_running():
            sync(fn(x))
        state.set_items_processed(n)
    bincount_xla.args_product([[1 << 16, 1 << 20], [256, 4096]])
    bincount_xla.set_arg_names(["n", "bins"])

    @benchmark(scope=NAME, registry=registry)
    def histogram_pallas(state: State):
        from repro.kernels.histogram import histogram
        n, bins = state.range(0), state.range(1)
        x = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, bins)
        sync(histogram(x, bins, chunk=4096))
        while state.keep_running():
            sync(histogram(x, bins, chunk=4096))
        state.set_items_processed(n)
    histogram_pallas.args([1 << 16, 256]).set_arg_names(["n", "bins"])


SCOPE = Scope(name=NAME, version="1.0.0",
              description="histogramming: XLA scatter vs Pallas one-hot",
              register=_register)
