"""MXU|Scope — the TCU|Scope analogue (paper Table IV: "Nvidia GPU tensor
cores" → TPU MXU systolic array).

Benchmarks the matrix unit through three paths at each size/dtype:
  * xla    — jnp.dot as XLA emits it (the production path);
  * pallas — our explicitly-tiled kernel (repro.kernels.matmul), interpret
             mode on CPU, native on TPU;
and reports achieved FLOP/s plus (for the TPU target) the modeled roofline
fraction at v5e peak.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Scope, State, benchmark, sync
from repro.core.registry import BenchmarkRegistry
from repro.core.sysinfo import TPU_V5E

NAME = "mxu"


def _register(registry: BenchmarkRegistry) -> None:
    def run_matmul(state: State, fn, dtype):
        n = state.range(0)
        x = jnp.ones((n, n), dtype)
        y = jnp.ones((n, n), dtype)
        sync(fn(x, y))                       # compile + warm
        while state.keep_running():
            sync(fn(x, y))
        flops = 2.0 * n * n * n
        state.counters["flops_per_call"] = flops
        state.counters["model_roofline_s"] = flops / TPU_V5E["peak_bf16_flops"]
        state.set_items_processed(int(flops))

    @benchmark(scope=NAME, registry=registry)
    def matmul_xla_f32(state: State):
        """Square f32 matmul via jnp.dot (XLA path)."""
        run_matmul(state, jax.jit(jnp.dot), jnp.float32)
    matmul_xla_f32.range_multiplier_args(256, 1024, mult=2)
    matmul_xla_f32.set_arg_names(["n"])

    @benchmark(scope=NAME, registry=registry)
    def matmul_xla_bf16(state: State):
        """Square bf16 matmul via jnp.dot — the MXU-native dtype."""
        run_matmul(state, jax.jit(jnp.dot), jnp.bfloat16)
    matmul_xla_bf16.range_multiplier_args(256, 1024, mult=2)
    matmul_xla_bf16.set_arg_names(["n"])

    @benchmark(scope=NAME, registry=registry)
    def matmul_pallas(state: State):
        """Tiled Pallas kernel (interpret-mode on CPU: correctness timing,
        not TPU performance — the BlockSpec tiling is the artifact)."""
        from repro.kernels.matmul import matmul
        n = state.range(0)
        run_matmul(state, lambda x, y: matmul(x, y, bm=min(256, n),
                                              bn=min(256, n),
                                              bk=min(256, n)), jnp.float32)
    matmul_pallas.args([256]).set_arg_names(["n"])


SCOPE = Scope(name=NAME, version="1.0.0",
              description="MXU/tensor-core matmul characterization",
              register=_register)
