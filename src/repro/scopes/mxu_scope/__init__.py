"""MXU|Scope — the TCU|Scope analogue (paper Table IV: "Nvidia GPU tensor
cores" → TPU MXU systolic array).

One ``matmul`` family benchmarks the matrix unit across typed axes —
``backend`` (xla: jnp.dot as XLA emits it, the production path; pallas:
our explicitly-tiled kernel, interpret mode on CPU, native on TPU),
``dtype`` (f32, bf16 — the MXU-native dtype) and size ``n`` — instead
of the three hand-copied per-variant families this scope used to carry.
The fixture allocates operands and builds the jitted callable untimed;
the runner's warm phase measures the first call (trace + XLA compile)
as ``compile_time_s``, so the steady-state numbers never include
compilation.  Reports achieved FLOP/s plus (for the TPU target) the
modeled roofline fraction at v5e peak.
"""
import jax
import jax.numpy as jnp

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry
from repro.core.sysinfo import TPU_V5E

NAME = "mxu"

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _register(registry: BenchmarkRegistry) -> None:
    def setup(params):
        n = params.n
        dtype = _DTYPES[params.dtype]
        if params.backend == "xla":
            fn = jax.jit(jnp.dot)
        else:
            from repro.kernels.matmul import matmul as pallas_matmul
            # block sizes come from the tuned defaults
            # (repro.kernels.tuning: tuned.json, env, or builtin)
            fn = lambda x, y: pallas_matmul(x, y)  # noqa: E731
        x = jnp.ones((n, n), dtype)
        y = jnp.ones((n, n), dtype)
        return fn, x, y

    @benchmark(scope=NAME, registry=registry)
    def matmul(state: State):
        """Square matmul through the selected backend/dtype.  The pallas
        rows are interpret-mode on CPU (correctness timing, not TPU
        performance — the BlockSpec tiling is the artifact).  The body
        delivers its product instead of blocking every iteration: the
        wall meter fences the whole pipelined batch once, before the
        clock stops."""
        fn, x, y = state.fixture
        while state.keep_running():
            state.deliver(fn(x, y))
        n = state.params.n
        flops = 2.0 * n * n * n
        state.counters["flops_per_call"] = flops
        state.counters["model_roofline_s"] = flops / TPU_V5E["peak_bf16_flops"]
        state.set_items_processed(int(flops))

    # pallas stays a single f32/256 point (interpret mode is slow on CPU);
    # the xla path sweeps the full dtype × size grid
    matmul.param_space(
        ParamSpace.product(backend=["xla", "pallas"],
                           dtype=["f32", "bf16"],
                           n=[256, 512, 1024])
        .where(lambda p: p.backend == "xla"
               or (p.dtype == "f32" and p.n == 256)))
    matmul.set_fixture(setup)
    # `python -m repro tune mxu/matmul` searches the Pallas block space
    # on the pallas instance and ships the winner as the kernel default
    matmul.set_tunable("matmul", bm=[64, 128, 256], bn=[64, 128, 256],
                       bk=[64, 128, 256],
                       instance={"backend": "pallas"})


SCOPE = Scope(name=NAME, version="2.0.0",
              description="MXU/tensor-core matmul characterization",
              register=_register)
