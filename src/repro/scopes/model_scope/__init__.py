"""Model|Scope — end-to-end characterization of the 10 assigned archs.

Two measurement modes:
  * measured — train/decode step wall time of REDUCED configs on the local
    device (framework-overhead + relative comparisons);
  * modeled  — the dry-run roofline records (results/dryrun/*.json) are
    surfaced as benchmark records, making §Roofline data flow through the
    same uniform JSON/ScopePlot pipeline as every other measurement —
    SCOPE's "one format for every abstraction level" applied to static
    analysis.
"""
import glob
import json
import os

import jax
import jax.numpy as jnp

from repro.core import FLAGS, ParamSpace, Scope, State, benchmark
from repro.core.registry import BenchmarkRegistry

NAME = "model"
_SMOKE_ARCHS = ["llama3.2-1b", "mamba2-780m", "deepseek-moe-16b",
                "jamba-v0.1-52b", "whisper-small"]


def _declare_flags(flags):
    flags.declare(f"{NAME}/dryrun_dir", owner=NAME, default="results/dryrun",
                  help="directory of dry-run cell JSONs to surface")


def _register(registry: BenchmarkRegistry) -> None:
    from repro.models import build, get_config

    def loss_step_setup(params):
        cfg = get_config(params.arch).reduced()
        api = build(cfg)
        weights = api.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 64), jnp.int32)}
        if cfg.family in ("audio", "encdec"):
            batch["frames"] = jnp.ones((2, cfg.enc_seq, cfg.d_model),
                                       jnp.float32)
        fn = jax.jit(lambda p, b: api.loss(p, b)[0])
        return fn, weights, batch

    @benchmark(scope=NAME, registry=registry)
    def loss_step_reduced(state: State):
        """Reduced-config loss step; the ``arch`` axis sweeps the smoke
        set of assigned architectures (one family, not a per-arch
        clone).  Model build + init happen in the fixture, untimed; the
        warm phase reports trace+compile as ``compile_time_s``; the
        loss value is the sync deliverable the wall meter fences on."""
        fn, weights, batch = state.fixture
        while state.keep_running():
            state.deliver(fn(weights, batch))
        state.set_items_processed(2 * 64)
    loss_step_reduced.param_space(ParamSpace.product(arch=_SMOKE_ARCHS))
    loss_step_reduced.set_fixture(loss_step_setup)

    @benchmark(scope=NAME, registry=registry)
    def dryrun_rooflines(state: State):
        """Surface dry-run roofline terms as counters (modeled, 1 iter)."""
        d = FLAGS.get(f"{NAME}/dryrun_dir", "results/dryrun")
        files = sorted(glob.glob(os.path.join(d, "*.json")))
        if not files:
            state.skip_with_message(f"no dry-run results under {d}")
            return
        n = 0
        bound = 0.0
        while state.keep_running():
            for f in files:
                rec = json.load(open(f))
                if rec.get("status") != "ok":
                    continue
                r = rec["roofline"]
                n += 1
                bound += max(r["compute_s"], r["memory_s"],
                             r["collective_s"])
        state.counters["cells"] = n
        state.counters["sum_bound_s"] = bound
    dryrun_rooflines.set_iterations(1)
    # pure host-side JSON aggregation — nothing async to fence
    dryrun_rooflines.set_sync(lambda ctx: None)


SCOPE = Scope(name=NAME, version="2.0.0",
              description="end-to-end arch characterization + rooflines",
              register=_register, declare_flags=_declare_flags)
