"""Comm|Scope — CPU-GPU/NVLink communication → mesh collectives over ICI.

Two measurement modes, mirroring the SCOPE philosophy of measuring the
same axis at different abstraction levels:

  * measured — run the collective on whatever local device mesh exists
    (1 device here → intra-chip copy baseline; the multi-device path is
    exercised by tests/test_comm_scope_multidev.py in a subprocess with 8
    host devices);
  * modeled  — analytic v5e ICI cost for the production meshes
    (ring all-reduce 2(n-1)/n, all-gather (n-1)/n, all-to-all (n-1)/n²)
    so the numbers feeding §Roofline are explicit and testable; one
    typed family with a ``kind`` axis covers every collective.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ParamSpace, Scope, State, benchmark
from repro.core.compat import shard_map
from repro.core.registry import BenchmarkRegistry
from repro.core.sysinfo import TPU_V5E

NAME = "comm"


def modeled_collective_seconds(kind: str, nbytes: int, axis_size: int,
                               link_bw: float = None) -> float:
    """Analytic ring-collective time on one ICI axis (v5e)."""
    bw = link_bw or TPU_V5E["ici_link_bandwidth"]
    n = axis_size
    if n <= 1:
        return 0.0
    factor = {"all_reduce": 2.0 * (n - 1) / n,
              "all_gather": (n - 1) / n,
              "reduce_scatter": (n - 1) / n,
              "all_to_all": (n - 1) / (n * n),
              "ppermute": 1.0}[kind]
    # bidirectional ring: 2 links usable per axis
    return factor * nbytes / (2 * bw)


def _register(registry: BenchmarkRegistry) -> None:
    def psum_setup(params):
        n = jax.device_count()
        elems = params.bytes // 4
        mesh = jax.make_mesh((n,), ("x",))
        x = jnp.ones((n, elems), jnp.float32)

        @jax.jit
        def f(x):
            return shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                             in_specs=jax.sharding.PartitionSpec("x"),
                             out_specs=jax.sharding.PartitionSpec())(x)
        return f, x

    @benchmark(scope=NAME, registry=registry)
    def all_reduce_measured(state: State):
        """psum over the local device mesh (1 device → copy baseline);
        mesh + jit construction live in the fixture, the reduced array
        is the sync deliverable."""
        f, x = state.fixture
        while state.keep_running():
            state.deliver(f(x))
        state.set_bytes_processed(state.params.bytes)
        state.counters["devices"] = jax.device_count()
    all_reduce_measured.range_multiplier_args(1 << 16, 1 << 22, mult=8)
    all_reduce_measured.set_arg_names(["bytes"])
    all_reduce_measured.set_fixture(psum_setup)

    @benchmark(scope=NAME, registry=registry)
    def collective_modeled_v5e(state: State):
        """Analytic v5e ICI collective over one mesh axis — the ``kind``
        axis replaces four per-collective family clones (feeds the
        §Roofline collective term)."""
        p = state.params
        t = modeled_collective_seconds(p.kind, p.bytes, p.axis)
        state.set_iteration_time(t)
        while state.keep_running():
            state.set_iteration_time(t)
        state.counters["modeled_s"] = t
        state.counters["axis_size"] = p.axis
        state.set_bytes_processed(p.bytes)

    collective_modeled_v5e.param_space(
        kind=["all_reduce", "all_gather", "reduce_scatter", "all_to_all"],
        bytes=[1 << 20, 1 << 24, 1 << 28],
        axis=[16, 256])
    collective_modeled_v5e.manual_time().set_iterations(1)


SCOPE = Scope(name=NAME, version="2.0.0",
              description="Interconnect collectives: measured + v5e model",
              register=_register)
