"""Batched serving engine: prefill/decode steps + continuous batching.

Slot-based continuous batching (vLLM-style scheduling, TPU-adapted):
  * a fixed pool of ``max_batch`` slots shares one padded KV/SSM cache —
    shapes are static, so there is exactly ONE compiled decode program;
  * arriving requests prefill into a free slot (per-slot prefill keeps the
    decode batch running between admissions; prefill programs are compiled
    per padded prompt-bucket);
  * every decode step advances ALL live slots one token; finished slots
    (EOS or max_tokens) free immediately and are refilled from the queue —
    no head-of-line blocking on long generations;
  * per-slot position counters mask attention to each slot's own history
    (the cache is padded to ``max_len``).

The hardware adaptation vs GPU serving stacks: instead of paged KV blocks
(pointer-chasing is hostile to the TPU's dense DMA model), slots use
contiguous per-slot cache regions with static shapes — the standard
TPU serving layout.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.logging import get_logger
from repro.models.api import ModelApi

log = get_logger("serve")


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    prompt_len: int = 0
    # generation stopped because the slot's cache filled (max_len), not
    # because of EOS/max_tokens — the output is complete but shorter
    # than requested
    truncated: bool = False


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    prompt_buckets: Tuple[int, ...] = (32, 64, 128, 256)
    cache_dtype: Any = jnp.bfloat16
    greedy: bool = True
    # fence (block_until_ready) decoded tokens before stamping
    # first_token_at/done_at, so TTFT/latency measure *delivery*.
    # False reverts to stamping at dispatch-return — enqueue time, the
    # async-dispatch bug class the wall meter fences in batch timing —
    # and exists so the regression test can measure the gap.
    fence_timestamps: bool = True


class ServeEngine:
    """Single-host engine driving a ModelApi; the multi-pod serve path
    reuses the same step functions under pjit (launch/serve.py)."""

    def __init__(self, api: ModelApi, params, cfg: ServeConfig):
        self.api = api
        self.cfg = cfg
        self.params = params
        self.queue: "collections.deque[Request]" = collections.deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        self._uid = 0

        # single shared cache for the whole slot pool, with PER-SLOT
        # position clocks (ragged decode)
        from repro.models import transformer
        from repro.models.api import family_module
        assert family_module(api.cfg) is transformer, \
            "ServeEngine drives decoder-only families (dense/moe/vlm)"
        self.cache = api.init_cache(cfg.max_batch, cfg.max_len,
                                    cfg.cache_dtype)
        self.cache["pos"] = jnp.zeros((cfg.max_batch,), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step_ragged(api.cfg, p, t, c))
        self._prefill_cache = {}
        # host-side per-slot position clocks (prefix + decoded tokens):
        # max_len exhaustion is a host decision, it must not force the
        # device cache
        self._slot_pos = [0] * cfg.max_batch
        #: queued + in-flight request count sampled once per step() —
        #: the queue-depth series latency meters average
        self.queue_depth_log: List[int] = []

    # -- public API -------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_tokens: int = 32,
               eos_id: Optional[int] = None,
               submitted_at: Optional[float] = None) -> Request:
        """Queue one request.  ``submitted_at`` lets open-loop drivers
        stamp the *scheduled arrival* instant so latency includes the
        queueing the arrival process created (default: now)."""
        prompt = np.asarray(prompt, np.int32)
        biggest = max(self.cfg.prompt_buckets)
        if len(prompt) > biggest:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the largest "
                f"prefill bucket ({biggest}); raise ServeConfig."
                f"prompt_buckets (currently {self.cfg.prompt_buckets}) "
                f"or chunk the prompt")
        if len(prompt) >= self.cfg.max_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit a "
                f"max_len={self.cfg.max_len} cache with room to decode; "
                f"raise ServeConfig.max_len")
        self._uid += 1
        req = Request(self._uid, prompt, max_tokens, eos_id,
                      submitted_at=(time.perf_counter()
                                    if submitted_at is None
                                    else submitted_at),
                      prompt_len=len(prompt))
        self.queue.append(req)
        return req

    def step(self) -> List[Request]:
        """One engine step: admit from the queue, decode every live slot
        one token.  Returns the requests that finished this step (empty
        when the pool is idle).  ``run`` is a loop over this; open-loop
        drivers interleave it with scheduled ``submit`` calls."""
        self._admit()
        depth = len(self.queue) + sum(1 for s in self.slots if s is not None)
        self.queue_depth_log.append(depth)
        if not any(s is not None for s in self.slots):
            return []
        return self._decode_step()

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue and slots drain.  Returns finished requests."""
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and not any(s is not None for s in self.slots):
                break
            finished.extend(self.step())
        return finished

    # -- internals ------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.cfg.prompt_buckets:
            if n <= b:
                return b
        raise ValueError(                      # unreachable via submit()
            f"no prompt bucket fits {n} tokens "
            f"(buckets: {self.cfg.prompt_buckets})")

    def _admit(self) -> None:
        for i in range(self.cfg.max_batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self._prefill_into_slot(i, req)
            self.slots[i] = req

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Per-slot prefill: bucket-padded single-row prefill, then splice
        the row's cache into the pool cache at ``slot``."""
        bucket = self._bucket(len(req.prompt))
        toks = np.zeros((1, bucket), np.int32)
        n = min(len(req.prompt), bucket)
        toks[0, :n] = req.prompt[:n]
        if bucket not in self._prefill_cache:
            def one_row_prefill(params, tokens, n):
                cache = self.api.init_cache(1, self.cfg.max_len,
                                            self.cfg.cache_dtype)
                return self.api.prefill(params, {"tokens": tokens}, cache,
                                        logit_pos=n - 1)
            self._prefill_cache[bucket] = jax.jit(one_row_prefill)
        logits_row, row_cache = self._prefill_cache[bucket](
            self.params, toks, n)
        # right-padded prompt: this slot's clock is n, so padded keys
        # beyond position n are masked by the per-slot prefix length
        row_cache = dict(row_cache, pos=jnp.asarray([n], jnp.int32))
        if self.cfg.fence_timestamps:
            jax.block_until_ready(logits_row)
        # fenced: the token is on the host — TTFT measures delivery;
        # unfenced: the dispatch just returned — TTFT measures enqueue
        req.first_token_at = time.perf_counter()
        tok = int(jnp.argmax(logits_row[0, -1]))
        req.output.append(tok)
        self.cache = _splice_row(self.cache, row_cache, slot)
        self._slot_pos[slot] = n
        self._pending_tok = getattr(self, "_pending_tok",
                                    np.zeros(self.cfg.max_batch, np.int32))
        self._pending_tok[slot] = tok

    def _decode_step(self) -> List[Request]:
        toks = jnp.asarray(self._pending_tok)[:, None]
        logits, self.cache = self._decode(self.params, toks, self.cache)
        if self.cfg.fence_timestamps:
            jax.block_until_ready(logits)
        stamp = time.perf_counter()
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        done: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.output.append(tok)
            self._pending_tok[i] = tok
            self._slot_pos[i] += 1
            # the slot's cache is full when the *next* decode would
            # write at max_len: terminate rather than overrun the
            # static cache (the request is truncated, not failed)
            exhausted = self._slot_pos[i] + 1 >= self.cfg.max_len
            if (len(req.output) >= req.max_tokens or
                    (req.eos_id is not None and tok == req.eos_id) or
                    exhausted):
                if exhausted and len(req.output) < req.max_tokens and \
                        not (req.eos_id is not None and tok == req.eos_id):
                    req.truncated = True
                req.done_at = stamp
                done.append(req)
                self.slots[i] = None
        return done

    # -- metrics ----------------------------------------------------------
    @staticmethod
    def summarize(reqs: List[Request]) -> Dict[str, float]:
        """Batch-level summary stats; robust to empty and all-failed
        batches (no request ever reached ``done_at``) — means and
        throughput report 0.0 rather than crashing mid-postmortem."""
        if not reqs:
            return {}
        ttft = [r.first_token_at - r.submitted_at for r in reqs
                if r.first_token_at is not None]
        lat = [r.done_at - r.submitted_at for r in reqs
               if r.done_at is not None]
        toks = sum(len(r.output) for r in reqs)
        finished = [r.done_at for r in reqs if r.done_at is not None]
        span = (max(finished) - min(r.submitted_at for r in reqs)
                if finished else 0.0)
        return {"requests": len(reqs), "tokens": toks,
                "ttft_mean_s": float(np.mean(ttft)) if ttft else 0.0,
                "latency_mean_s": float(np.mean(lat)) if lat else 0.0,
                "throughput_tok_s": toks / span if span > 0 else 0.0}


def _splice_row(pool_cache, row_cache, slot: int):
    """Copy a 1-row cache into slot ``slot`` of the pool cache.

    Batch dim differs by cache kind: [L,B,...] arrays have it at axis 1,
    hybrid ssm entries at axis 2; 'pos' is a scalar (shared clock — per
    slot masking uses each row's own written prefix, padded rows attend to
    zeros which are masked by cache_len; the engine keeps one global pos =
    max over slots, acceptable because shorter slots' tails are zero-value
    keys with near-zero attention mass... see tests/test_serve.py for the
    correctness check).
    """
    def splice(pool, row):
        if pool.ndim == 0:                     # scalar pos (unused here)
            return jnp.maximum(pool, row)
        if pool.ndim == 1 and row.ndim == 1 and row.shape[0] == 1:
            return pool.at[slot].set(row[0])   # per-slot pos vector
        if pool.ndim == 1 and row.ndim == 0:
            return pool.at[slot].set(row)
        if pool.shape == row.shape:
            # max_batch == 1: the pool IS one row, there is no axis to
            # search for (the size-1 batch dim matches everywhere) —
            # without this case a single-slot engine silently drops the
            # prefilled cache and decodes over zeros
            return row
        if pool.shape[0] != row.shape[0]:      # stacked-first? not expected
            return pool
        # find the batch axis: first axis where sizes differ
        for ax in range(1, pool.ndim):
            if row.shape[ax] == 1 and pool.shape[ax] > 1:
                idx = [slice(None)] * pool.ndim
                idx[ax] = slice(slot, slot + 1)
                return pool.at[tuple(idx)].set(row)
        return pool
    return jax.tree_util.tree_map(splice, pool_cache, row_cache)
