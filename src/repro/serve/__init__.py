"""repro.serve — batched LM serving on top of the model API."""
from .engine import ServeConfig, ServeEngine, Request

__all__ = ["ServeConfig", "ServeEngine", "Request"]
