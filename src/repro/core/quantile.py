"""Percentile statistics for per-sample meters (jax/numpy-free).

Means are the wrong statistic for millions-of-users traffic; the
latency meter (:mod:`repro.core.measure`) reports tails instead.  Two
estimators live here:

  * :func:`percentile` — the exact linear-interpolation quantile
    (numpy's default method, reimplemented so workers never import an
    array library for a handful of floats).  Exact answers are what
    land on records: per-batch sample counts are small enough that
    exactness is free;
  * :class:`StreamingQuantile` — the P² algorithm (Jain & Chlamtac
    1985): a single quantile tracked in O(1) memory with five markers,
    exact below five samples.  This is the estimator a fleet-scale
    sample channel would switch to when per-request sample lists stop
    fitting in memory; tests pin its agreement with the exact path.

Merging: per-shard sample lists combine with :func:`combine` (a sort —
order- and grouping-invariant by construction), so percentiles computed
from ``combine(a, b)`` and ``combine(b, a)`` are byte-identical however
the orchestrator sharded the work.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

#: The tail grid the latency meter reports, as (suffix, quantile).
TAIL_QUANTILES = (("p50", 0.50), ("p90", 0.90), ("p99", 0.99),
                  ("p999", 0.999))


def percentile(samples: Sequence[float], q: float) -> float:
    """Exact quantile ``q`` in [0, 1] with linear interpolation.

    Matches ``numpy.percentile(..., method="linear")``.  Raises
    ``ValueError`` on an empty sample set — callers decide what an
    absent measurement means; this function never invents a number.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1] (got {q!r})")
    if not samples:
        raise ValueError("percentile of an empty sample set")
    xs = sorted(float(v) for v in samples)
    if len(xs) == 1:
        return xs[0]
    h = (len(xs) - 1) * q
    lo = int(h)
    hi = min(lo + 1, len(xs) - 1)
    frac = h - lo
    return xs[lo] + (xs[hi] - xs[lo]) * frac


def tail_percentiles(samples: Sequence[float],
                     prefix: str = "") -> Dict[str, float]:
    """The standard tail grid (p50/p90/p99/p999) as counter-ready keys:
    ``{prefix}p50_s`` ... — empty dict on no samples."""
    if not samples:
        return {}
    xs = sorted(float(v) for v in samples)
    return {f"{prefix}{suffix}_s": percentile(xs, q)
            for suffix, q in TAIL_QUANTILES}


def combine(*sample_lists: Iterable[float]) -> List[float]:
    """Merge per-shard sample lists into one canonical (sorted) list.

    Sorting makes the merge order- and grouping-invariant: percentiles
    over ``combine(a, b, c)`` equal those over ``combine(c, combine(b,
    a))`` byte-for-byte, which is what keeps latency counters identical
    across ``--jobs``/``--shard-grain`` choices.
    """
    out: List[float] = []
    for xs in sample_lists:
        out.extend(float(v) for v in xs)
    out.sort()
    return out


class StreamingQuantile:
    """P² single-quantile estimator (Jain & Chlamtac 1985), O(1) memory.

    Five markers track (min, q/2, q, (1+q)/2, max); each observation
    adjusts marker heights with a piecewise-parabolic fit.  Below five
    observations the estimate is exact (sorted buffer).  Duplicates and
    constant streams are handled by the linear fallback the paper
    specifies (the parabolic step is skipped when it would leave the
    bracket).
    """

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"streaming quantile needs 0 < q < 1 "
                             f"(got {q!r})")
        self.q = q
        self._n = 0
        self._heights: List[float] = []          # marker heights
        self._pos: List[float] = []              # actual positions
        self._want: List[float] = []             # desired positions
        self._inc = (0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0)

    @property
    def count(self) -> int:
        return self._n

    def observe(self, x: float) -> None:
        x = float(x)
        self._n += 1
        if self._n <= 5:
            self._heights.append(x)
            self._heights.sort()
            if self._n == 5:
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0, 1.0 + 2.0 * self.q, 1.0 + 4.0 * self.q,
                              3.0 + 2.0 * self.q, 5.0]
            return
        h = self._heights
        # locate the cell and bump marker positions above it
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = next(i for i in range(4) if h[i] <= x < h[i + 1])
        for i in range(k + 1, 5):
            self._pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._inc[i]
        # adjust the three interior markers toward their desired spots
        for i in (1, 2, 3):
            d = self._want[i] - self._pos[i]
            if (d >= 1.0 and self._pos[i + 1] - self._pos[i] > 1.0) or \
                    (d <= -1.0 and self._pos[i - 1] - self._pos[i] < -1.0):
                step = 1.0 if d >= 1.0 else -1.0
                cand = self._parabolic(i, step)
                if not h[i - 1] < cand < h[i + 1]:
                    cand = self._linear(i, step)
                h[i] = cand
                self._pos[i] += step

    def _parabolic(self, i: int, d: float) -> float:
        h, p = self._heights, self._pos
        return h[i] + d / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + d) * (h[i + 1] - h[i])
            / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - d) * (h[i] - h[i - 1])
            / (p[i] - p[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, p = self._heights, self._pos
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (p[j] - p[i])

    def value(self) -> float:
        """Current estimate; exact (interpolated) below five samples."""
        if self._n == 0:
            raise ValueError("streaming quantile has no observations")
        if self._n < 5:
            return percentile(self._heights, self.q)
        return self._heights[2]
