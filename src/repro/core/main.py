"""The SCOPE binary entry point (paper Fig. 1, ``python -m repro``).

Subcommands::

    python -m repro [run] [flags...]       # run benchmarks (default)
    python -m repro plan [flags...]        # print the work plan + costs
    python -m repro ci [flags...]          # incremental run + drift gate
    python -m repro tune <family> [...]    # autotune a kernel's blocks
    python -m repro compare A.json B.json  # diff two result documents
    python -m repro report <run-id>        # HTML/Markdown run report
    python -m repro query [filters...]     # filter/aggregate run history
    python -m repro store <index|ingest|status>  # manage the result store

Startup sequence mirrors the paper's run stage:

  1. load scopes (download/configure analogue — imports, flag declaration)
  2. run pre-parse init hooks
  3. parse CLI (core flags + every scope's declared flags)
  4. run post-parse init hooks
  5. enable/disable scopes, register their benchmarks
  6. build the work plan and hand it to the run orchestrator
     (``--jobs N`` parallelizes across failure-isolated workers;
     ``--shard-grain benchmark`` schedules individual benchmark
     instances, ``--resume <run-id>`` completes an interrupted run;
     ``--meters`` selects the measurement meter stack every worker
     drives — see repro.core.orchestrate / repro.core.measure), write
     the merged GB-JSON data file and append the run to
     ``<results-dir>/history.jsonl``
  7. optionally diff against / store a baseline (repro.core.baseline)

``--help`` on the binary and on every subcommand carries copy-pasteable
examples (repro.core.cli_examples); tests assert they stay parseable.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

from . import logging as scope_logging
from .baseline import (compare_documents, compare_main, format_comparisons,
                       gate_failures, load_document, save_baseline,
                       summarize)
from .benchmark import parse_param_filter
from .cli_examples import epilog
from .flags import FLAGS
from .hooks import HOOKS
from .measure import parse_meters
from .orchestrate import OrchestratorOptions, execute
from .plan import build_plan, load_cost_hints, scope_worklist
from .registry import REGISTRY
from .runner import RunOptions, write_json
from .scope import ScopeManager

log = scope_logging.get_logger("main")

_OVERVIEW = """\
usage: python -m repro [COMMAND] [flags...]

The SCOPE binary: run benchmark scopes, plan/schedule the work, compare
results, and render reports.

commands:
  run       run benchmarks (the default when COMMAND is omitted)
  plan      print the work plan with predicted costs and worker bins
  ci        continuous-benchmarking entrypoint: delta-plan against the
            run history (only fingerprint-stale instances re-measure),
            run, gate against windowed drift, report — exit 1 on
            regression (docs/continuous-benchmarking.md)
  lint      static-analyze benchmark families for measurement-corrupting
            bugs (nothing runs, nothing is timed)
  tune      search a tunable family's kernel block space and ship the
            winner as the kernel's tuned.json default
  compare   mean/stddev-aware diff of two result documents
  report    static HTML/Markdown report for a run or the run history
            (--serve adds a live dashboard over the result store)
  query     filter/aggregate the run history (store-indexed when
            history.db exists; output equals a direct JSONL scan)
  store     manage the SQLite result store: index (incremental),
            ingest (merge fleet shards), status

`python -m repro COMMAND --help` shows each command's flags and
examples.  Start-here docs: README.md, docs/run-pipeline.md.
"""


def main(argv: Optional[List[str]] = None,
         scope_modules: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] in ("-h", "--help"):
        print(_OVERVIEW)
        print(epilog("run"))
        return 0
    if argv and argv[0] == "compare":
        return compare_main(argv[1:])
    if argv and argv[0] == "report":
        from repro.scopeplot.report import report_main
        return report_main(argv[1:])
    if argv and argv[0] == "query":
        from repro.store.cli import query_main
        return query_main(argv[1:])
    if argv and argv[0] == "store":
        from repro.store.cli import store_main
        return store_main(argv[1:])
    if argv and argv[0] == "plan":
        return plan_main(argv[1:], scope_modules)
    if argv and argv[0] == "ci":
        from .ci import ci_main
        return ci_main(argv[1:], scope_modules)
    if argv and argv[0] == "lint":
        from .lint import lint_main
        return lint_main(argv[1:], scope_modules)
    if argv and argv[0] == "tune":
        from .tune import tune_main
        return tune_main(argv[1:], scope_modules)
    if argv and argv[0] == "run":
        argv = argv[1:]
    return run_main(argv, scope_modules)


def _setup_scopes(scope_modules: Optional[List[str]],
                  enable: Optional[List[str]], disable: List[str],
                  rest: List[str]) -> Tuple[Optional[ScopeManager], int]:
    """Steps 1–5 of the startup sequence, shared by run and plan."""
    mgr = ScopeManager()
    mgr.load(scope_modules)

    rc = HOOKS.run_pre_parse()
    if rc is not None:
        return None, rc

    FLAGS.parse(rest)
    scope_logging.set_level(FLAGS.get("log_level", "INFO"))

    rc = HOOKS.run_post_parse()
    if rc is not None:
        return None, rc

    mgr.configure(enable=enable, disable=disable)
    return mgr, 0


def build_run_parser() -> argparse.ArgumentParser:
    """Core run options (scope flags are parsed separately via FLAGS)."""
    sel = argparse.ArgumentParser(prog="python -m repro run",
                                  add_help=False, epilog=epilog("run"),
                                  formatter_class=
                                  argparse.RawDescriptionHelpFormatter)
    sel.add_argument("--enable-scope", action="append", default=None,
                     help="enable ONLY these scopes (repeatable)")
    sel.add_argument("--disable-scope", action="append", default=[],
                     help="disable these scopes (repeatable)")
    sel.add_argument("--list-scopes", action="store_true")
    sel.add_argument("--param", action="append", default=[],
                     metavar="KEY=VALUE",
                     help="run only instances whose typed parameter KEY "
                          "equals VALUE (repeatable; same KEY twice ORs "
                          "the values, distinct KEYs AND together — e.g. "
                          "--param dtype=bf16 --param backend=pallas)")
    sel.add_argument("--meters", default=None, metavar="LIST",
                     help="comma-separated measurement meters driven "
                          "around every batch (available: wall, cpu, "
                          "costmodel, latency; default wall,cpu).  wall "
                          "and cpu are always included — they are the "
                          "record's time sources; costmodel adds "
                          "flops/bytes_accessed counters from the "
                          "fixture's jitted callable; latency consumes "
                          "per-request samples (state.observe) and adds "
                          "tail-percentile/goodput counters "
                          "(docs/measurement.md, docs/serving.md)")
    sel.add_argument("--slo-ms", type=float, default=None, metavar="MS",
                     help="latency objective in milliseconds for the "
                          "latency meter: goodput_rps counts only "
                          "requests completing within the SLO and "
                          "slo_attainment reports the fraction that did "
                          "(default: no SLO — every completed request "
                          "counts toward goodput)")
    sel.add_argument("--aggregates-only", action="store_true",
                     help="with --benchmark_repetitions > 1, report only "
                          "the mean/median/stddev aggregate records "
                          "(throughput, compile time and meter counters "
                          "are carried onto them)")
    sel.add_argument("--lint", action="store_true",
                     help="static-analyze the selected families before "
                          "running (python -m repro lint): error-severity "
                          "findings abort the run before anything is "
                          "timed")
    sel.add_argument("--strict", action="store_true",
                     help="with --lint, abort on warning-severity "
                          "findings too")
    sel.add_argument("--jobs", type=int, default=1,
                     help="run work in N parallel isolated workers")
    sel.add_argument("--isolate", default="auto",
                     choices=["auto", "inline", "pool", "subprocess"],
                     help="worker isolation (auto: inline when --jobs 1; "
                          "at benchmark grain, pool and subprocess both "
                          "run one batch interpreter per worker bin)")
    sel.add_argument("--shard-grain", default="auto",
                     choices=["auto", "benchmark", "scope"],
                     help="schedulable unit (auto: benchmark when "
                          "--jobs > 1 or resuming, scope otherwise)")
    sel.add_argument("--results-dir", default="results",
                     help="persist shards + manifest.json + merged.json "
                          "under <dir>/<run-id>/ and append the run to "
                          "<dir>/history.jsonl (default: results; pass "
                          "an empty string to keep the run ephemeral)")
    sel.add_argument("--run-id", default=None,
                     help="run directory name (default: timestamp)")
    sel.add_argument("--resume", default=None, metavar="RUN_ID",
                     help="re-open <results-dir>/<RUN_ID> and run only the "
                          "instances whose shard is missing or failed")
    sel.add_argument("--since", nargs="?", const="", default=None,
                     metavar="ISO",
                     help="delta run: skip instances whose current "
                          "fingerprint (body/fixture/kernel source, "
                          "params, tuned artifact, jax version) already "
                          "has a measured history record on this "
                          "machine; their latest records replay into "
                          "the merged document as cached.  An optional "
                          "ISO prefix bounds freshness (records older "
                          "than it don't count)")
    sel.add_argument("--costs", default=None, metavar="PATH",
                     help="prior run directory or GB-JSON document used as "
                          "per-instance cost hints for LPT scheduling")
    sel.add_argument("--baseline", default=None,
                     help="compare this run against a stored baseline "
                          "document/run directory (a history.jsonl path "
                          "gates against the windowed run history)")
    sel.add_argument("--save-baseline", default=None,
                     help="store the merged document as a baseline at PATH")
    return sel


def _print_run_help(sel: argparse.ArgumentParser,
                    scope_modules: Optional[List[str]]) -> None:
    """Core options + every scope flag, in one --help."""
    mgr = ScopeManager()
    mgr.load(scope_modules)
    print(sel.format_help())
    print("scope flags (declared by the loaded scopes):")
    flag_parser = FLAGS.build_parser(
        argparse.ArgumentParser(prog="python -m repro run",
                                add_help=False, usage=argparse.SUPPRESS))
    print(flag_parser.format_help())


def _delta_cached(mgr, results_dir: str, pattern: str,
                  param_filter: Optional[Dict[str, List[str]]],
                  fingerprints: Dict[str, str], since: str
                  ) -> Dict[str, Dict[str, Any]]:
    """``--since`` delta split: instance_id → vouching history record.

    Consults the run history (store fast path via
    :func:`repro.core.history.load_history`, scan fallback) for this
    machine's sysinfo digest; instances whose current fingerprint
    already has a fresh measured record are returned for cached
    materialization, the rest will execute.
    """
    from .fingerprint import delta_split
    from .history import history_path, load_history
    from .sysinfo import build_context, context_digest
    hpath = history_path(results_dir)
    records = load_history(hpath) if os.path.exists(hpath) else []
    digest = context_digest(build_context())
    plan = build_plan(mgr, REGISTRY, pattern, param_filter=param_filter)
    pending, cached = delta_split(plan.items, fingerprints, records,
                                  digest, since=since)
    log.info("delta plan (--since%s): %d fresh (cached) / %d to run of "
             "%d instance(s)", f" {since}" if since else "",
             len(cached), len(pending), len(plan.items))
    return cached


def run_main(argv: List[str],
             scope_modules: Optional[List[str]] = None) -> int:
    # Scope selection + orchestration are core-level (not scope flags),
    # parsed separately from the FLAGS registry.
    sel = build_run_parser()
    if any(a in ("-h", "--help") for a in argv):
        _print_run_help(sel, scope_modules)
        return 0
    sel_ns, rest = sel.parse_known_args(argv)

    try:
        param_filter = parse_param_filter(sel_ns.param)
    except ValueError as e:
        log.error("%s", e)
        return 2

    meters = None
    if sel_ns.meters:
        try:
            meters = parse_meters(sel_ns.meters)
        except ValueError as e:
            log.error("%s", e)
            return 2

    if sel_ns.resume and not sel_ns.results_dir:
        log.error("--resume requires --results-dir")
        return 2
    if sel_ns.resume and sel_ns.shard_grain == "scope":
        log.error("--resume requires benchmark shard grain "
                  "(drop --shard-grain scope)")
        return 2
    if sel_ns.since is not None and not sel_ns.results_dir:
        log.error("--since requires --results-dir (the run history is "
                  "the freshness source)")
        return 2
    if sel_ns.since is not None and sel_ns.shard_grain == "scope":
        log.error("--since requires benchmark shard grain "
                  "(drop --shard-grain scope)")
        return 2

    # load the baseline up front: a bad path must fail before the run,
    # and a history.jsonl baseline must be snapshotted before this run
    # appends itself to the same file
    base_doc = None
    if sel_ns.baseline:
        try:
            base_doc = load_document(sel_ns.baseline)
        except (OSError, json.JSONDecodeError) as e:
            log.error("baseline %s unreadable: %s", sel_ns.baseline, e)
            return 2

    mgr, rc = _setup_scopes(scope_modules, sel_ns.enable_scope,
                            sel_ns.disable_scope, rest)
    if mgr is None:
        return rc
    if sel_ns.list_scopes:
        for name, status in sorted(mgr.status().items()):
            print(f"{name:24s} {status}")
        return 0

    mgr.register_all()

    pattern = FLAGS.get("benchmark_filter", ".*")
    benches = REGISTRY.filter(pattern, params=param_filter)
    if FLAGS.get("benchmark_list_tests"):
        from .benchmark import match_params
        for b in benches:
            for name, params in b.instances():
                if match_params(params, param_filter):
                    print(name)
        return 0
    if not benches:
        log.error("no benchmarks match %r%s", pattern,
                  f" with --param {sel_ns.param}" if param_filter else "")
        return 1
    if sel_ns.lint:
        # pre-flight: a family the linter can prove mismeasures must not
        # burn a run.  Same rules as `python -m repro lint`; findings go
        # to stderr so the GB-JSON stream on stdout stays parseable.
        from .lint import run_lint
        report = run_lint(benches, scope_names=sorted(
            {b.scope for b in benches}))
        if report.findings:
            print(report.format_text(), file=sys.stderr)
        if report.failed(sel_ns.strict):
            log.error("lint pre-flight failed (%s); nothing was run",
                      report.summary())
            return 1
        log.info("lint pre-flight clean: %s", report.summary())
    # don't dispatch workers for scopes the filter selects nothing from —
    # each would pay a fresh interpreter + JAX import to return 0 records
    matched = {b.scope for b in benches}
    mgr.configure(disable=[name for name, _ in scope_worklist(mgr)
                           if name not in matched])

    # fingerprints ride on every run's context so history records carry
    # them (delta planning and coverage read them back)
    from .fingerprint import registry_fingerprints
    fingerprints = registry_fingerprints(benches)

    cached = None
    if sel_ns.since is not None:
        cached = _delta_cached(mgr, sel_ns.results_dir, pattern,
                               param_filter, fingerprints, sel_ns.since)

    opts = OrchestratorOptions(
        jobs=sel_ns.jobs,
        isolate=sel_ns.isolate,
        shard_grain=sel_ns.shard_grain,
        benchmark_filter=pattern,
        run=RunOptions(
            min_time=FLAGS.get("benchmark_min_time", 0.05),
            repetitions=FLAGS.get("benchmark_repetitions", 1),
            report_aggregates_only=sel_ns.aggregates_only,
            param_filter=param_filter,
            meters=meters,
            slo_ms=sel_ns.slo_ms,
        ),
        flag_values={s.name: FLAGS.get(s.name) for s in FLAGS.declared()},
        results_dir=sel_ns.results_dir or None,
        run_id=sel_ns.resume or sel_ns.run_id,
        resume=bool(sel_ns.resume),
        cost_source=sel_ns.costs,
        cached_results=cached,
    )
    result = execute(mgr, REGISTRY, opts,
                     context_extra={"scopes": mgr.status(),
                                    "fingerprints": fingerprints})
    doc = result.doc

    out = FLAGS.get("benchmark_out")
    if out:
        write_json(doc, out)
        log.info("wrote %s (%d records)", out, len(doc["benchmarks"]))
    else:
        write_json(doc, sys.stdout)
        print()
    if result.out_dir:
        log.info("run %s persisted under %s (render it: python -m repro "
                 "report %s)", result.run_id, result.out_dir,
                 result.run_id)

    rc = 0
    if base_doc is not None:
        comps = compare_documents(base_doc, doc)
        print(format_comparisons(comps), file=sys.stderr)
        counts = summarize(comps)
        log.info("baseline diff: %s",
                 ", ".join(f"{v} {k}" for k, v in sorted(counts.items())))
        if gate_failures(comps):
            rc = 1
    if sel_ns.save_baseline:
        save_baseline(doc, sel_ns.save_baseline)
    return rc


def build_plan_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro plan",
                                 add_help=False, epilog=epilog("plan"),
                                 formatter_class=
                                 argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--enable-scope", action="append", default=None)
    ap.add_argument("--disable-scope", action="append", default=[])
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker count the bin column assumes")
    ap.add_argument("--costs", default=None, metavar="PATH",
                    help="prior run directory or GB-JSON document used as "
                         "per-instance cost hints")
    ap.add_argument("--param", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="plan only instances whose typed parameter KEY "
                         "equals VALUE (repeatable)")
    ap.add_argument("--results-dir", default="results",
                    help="history location --since consults "
                         "(default: results)")
    ap.add_argument("--since", nargs="?", const="", default=None,
                    metavar="ISO",
                    help="delta plan: drop instances whose current "
                         "fingerprint already has a measured history "
                         "record on this machine (optional ISO prefix "
                         "bounds freshness)")
    return ap


def plan_main(argv: List[str],
              scope_modules: Optional[List[str]] = None) -> int:
    """``python -m repro plan`` — print the work plan with predicted costs.

    Shows exactly what a ``--shard-grain benchmark`` run would schedule:
    every benchmark instance with its stable ID, its predicted cost
    (``--costs`` hints, else the plan default), and the worker bin LPT
    assigns it to for the given ``--jobs``.
    """
    ap = build_plan_parser()
    if any(a in ("-h", "--help") for a in argv):
        print(ap.format_help())
        return 0
    ns, rest = ap.parse_known_args(argv)

    try:
        param_filter = parse_param_filter(ns.param)
    except ValueError as e:
        log.error("%s", e)
        return 2

    mgr, rc = _setup_scopes(scope_modules, ns.enable_scope,
                            ns.disable_scope, rest)
    if mgr is None:
        return rc
    mgr.register_all()

    hints = {}
    if ns.costs:
        try:
            hints = load_cost_hints(ns.costs)
        except (OSError, json.JSONDecodeError) as e:
            log.warning("cost source %s unreadable (%s); planning without "
                        "hints", ns.costs, e)
    pattern = FLAGS.get("benchmark_filter", ".*")
    plan = build_plan(mgr, REGISTRY, pattern, cost_hints=hints,
                      param_filter=param_filter)
    if not plan.items:
        log.error("no benchmarks match %r%s", pattern,
                  f" with --param {ns.param}" if param_filter else "")
        return 1

    items = plan.items
    n_cached = 0
    if ns.since is not None:
        from .fingerprint import registry_fingerprints
        fingerprints = registry_fingerprints(REGISTRY.filter(
            pattern, params=param_filter))
        cached = _delta_cached(mgr, ns.results_dir, pattern, param_filter,
                               fingerprints, ns.since)
        items = [i for i in plan.items if i.instance_id not in cached]
        n_cached = len(plan.items) - len(items)
        if not items:
            print(f"0 instance(s) to run; all {n_cached} "
                  f"fingerprint-fresh (--since)")
            return 0

    bins = plan.bins(ns.jobs, items)
    bin_of = {item.instance_id: k
              for k, b in enumerate(bins) for item in b}
    width = max(len(i.name) for i in items)
    print(f"{'instance':<{width}}  {'cost_s':>9}  {'hint':>5}  bin  "
          f"instance_id")
    for item in items:
        hint = "prior" if item.cost is not None else "def"
        print(f"{item.name:<{width}}  {plan.cost_of(item):>9.4f}  "
              f"{hint:>5}  {bin_of[item.instance_id]:>3d}  "
              f"{item.instance_id}")
    loads = [sum(plan.cost_of(i) for i in b) for b in bins]
    cached_note = (f" ({n_cached} fingerprint-fresh instance(s) pruned "
                   f"by --since)" if n_cached else "")
    print(f"\n{len(items)} instance(s) across {len(bins)} worker "
          f"bin(s); predicted total "
          f"{sum(plan.cost_of(i) for i in items):.2f}s, "
          f"makespan {max(loads):.2f}s{cached_note}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
