"""The SCOPE binary entry point (paper Fig. 1, ``python -m repro``).

Startup sequence mirrors the paper's run stage:

  1. load scopes (download/configure analogue — imports, flag declaration)
  2. run pre-parse init hooks
  3. parse CLI (core flags + every scope's declared flags)
  4. run post-parse init hooks
  5. enable/disable scopes, register their benchmarks
  6. filter, run, write the Google-Benchmark JSON data file
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from . import logging as scope_logging
from .flags import FLAGS
from .hooks import HOOKS
from .registry import REGISTRY
from .runner import RunOptions, run_benchmarks, write_json
from .scope import ScopeManager

log = scope_logging.get_logger("main")


def main(argv: Optional[List[str]] = None,
         scope_modules: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)

    # Scope selection is core-level (not a scope flag), parse separately.
    sel = argparse.ArgumentParser(add_help=False)
    sel.add_argument("--enable-scope", action="append", default=None,
                     help="enable ONLY these scopes (repeatable)")
    sel.add_argument("--disable-scope", action="append", default=[],
                     help="disable these scopes (repeatable)")
    sel.add_argument("--list-scopes", action="store_true")
    sel_ns, rest = sel.parse_known_args(argv)

    mgr = ScopeManager()
    mgr.load(scope_modules)

    rc = HOOKS.run_pre_parse()
    if rc is not None:
        return rc

    FLAGS.parse(rest)
    scope_logging.set_level(FLAGS.get("log_level", "INFO"))

    rc = HOOKS.run_post_parse()
    if rc is not None:
        return rc

    mgr.configure(enable=sel_ns.enable_scope, disable=sel_ns.disable_scope)
    if sel_ns.list_scopes:
        for name, status in sorted(mgr.status().items()):
            print(f"{name:24s} {status}")
        return 0

    mgr.register_all()

    pattern = FLAGS.get("benchmark_filter", ".*")
    benches = REGISTRY.filter(pattern)
    if FLAGS.get("benchmark_list_tests"):
        for b in benches:
            for name, _ in b.instances():
                print(name)
        return 0
    if not benches:
        log.error("no benchmarks match %r", pattern)
        return 1

    opts = RunOptions(
        min_time=FLAGS.get("benchmark_min_time", 0.05),
        repetitions=FLAGS.get("benchmark_repetitions", 1),
    )
    doc = run_benchmarks(benches, opts,
                         context_extra={"scopes": mgr.status()})
    out = FLAGS.get("benchmark_out")
    if out:
        write_json(doc, out)
        log.info("wrote %s (%d records)", out, len(doc["benchmarks"]))
    else:
        write_json(doc, sys.stdout)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
