"""The SCOPE binary entry point (paper Fig. 1, ``python -m repro``).

Subcommands::

    python -m repro [run] [flags...]     # run benchmarks (default)
    python -m repro compare A.json B.json  # diff two result documents

Startup sequence mirrors the paper's run stage:

  1. load scopes (download/configure analogue — imports, flag declaration)
  2. run pre-parse init hooks
  3. parse CLI (core flags + every scope's declared flags)
  4. run post-parse init hooks
  5. enable/disable scopes, register their benchmarks
  6. filter, then hand the enabled scopes to the run orchestrator
     (``--jobs N`` parallelizes scopes across failure-isolated workers;
     see repro.core.orchestrate), write the merged GB-JSON data file
  7. optionally diff against / store a baseline (repro.core.baseline)
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from . import logging as scope_logging
from .baseline import (compare_documents, compare_main, format_comparisons,
                       gate_failures, load_document, save_baseline,
                       summarize)
from .flags import FLAGS
from .hooks import HOOKS
from .orchestrate import OrchestratorOptions, execute
from .registry import REGISTRY
from .runner import RunOptions, write_json
from .scope import ScopeManager

log = scope_logging.get_logger("main")


def main(argv: Optional[List[str]] = None,
         scope_modules: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "compare":
        return compare_main(argv[1:])
    if argv and argv[0] == "run":
        argv = argv[1:]
    return run_main(argv, scope_modules)


def run_main(argv: List[str],
             scope_modules: Optional[List[str]] = None) -> int:
    # Scope selection + orchestration are core-level (not scope flags),
    # parsed separately from the FLAGS registry.
    sel = argparse.ArgumentParser(add_help=False)
    sel.add_argument("--enable-scope", action="append", default=None,
                     help="enable ONLY these scopes (repeatable)")
    sel.add_argument("--disable-scope", action="append", default=[],
                     help="disable these scopes (repeatable)")
    sel.add_argument("--list-scopes", action="store_true")
    sel.add_argument("--jobs", type=int, default=1,
                     help="run scopes in N parallel isolated workers")
    sel.add_argument("--isolate", default="auto",
                     choices=["auto", "inline", "pool", "subprocess"],
                     help="worker isolation (auto: inline when --jobs 1, "
                          "process pool otherwise)")
    sel.add_argument("--results-dir", default=None,
                     help="persist per-scope shards + merged.json under "
                          "<dir>/<run-id>/")
    sel.add_argument("--run-id", default=None,
                     help="run directory name (default: timestamp)")
    sel.add_argument("--baseline", default=None,
                     help="compare this run against a stored baseline "
                          "document/run directory")
    sel.add_argument("--save-baseline", default=None,
                     help="store the merged document as a baseline at PATH")
    sel_ns, rest = sel.parse_known_args(argv)

    mgr = ScopeManager()
    mgr.load(scope_modules)

    rc = HOOKS.run_pre_parse()
    if rc is not None:
        return rc

    FLAGS.parse(rest)
    scope_logging.set_level(FLAGS.get("log_level", "INFO"))

    rc = HOOKS.run_post_parse()
    if rc is not None:
        return rc

    mgr.configure(enable=sel_ns.enable_scope, disable=sel_ns.disable_scope)
    if sel_ns.list_scopes:
        for name, status in sorted(mgr.status().items()):
            print(f"{name:24s} {status}")
        return 0

    mgr.register_all()

    pattern = FLAGS.get("benchmark_filter", ".*")
    benches = REGISTRY.filter(pattern)
    if FLAGS.get("benchmark_list_tests"):
        for b in benches:
            for name, _ in b.instances():
                print(name)
        return 0
    if not benches:
        log.error("no benchmarks match %r", pattern)
        return 1
    # don't dispatch workers for scopes the filter selects nothing from —
    # each would pay a fresh interpreter + JAX import to return 0 records
    matched = {b.scope for b in benches}
    mgr.configure(disable=[name for name, _ in mgr.dispatchable()
                           if name not in matched])

    opts = OrchestratorOptions(
        jobs=sel_ns.jobs,
        isolate=sel_ns.isolate,
        benchmark_filter=pattern,
        run=RunOptions(
            min_time=FLAGS.get("benchmark_min_time", 0.05),
            repetitions=FLAGS.get("benchmark_repetitions", 1),
        ),
        flag_values={s.name: FLAGS.get(s.name) for s in FLAGS.declared()},
        results_dir=sel_ns.results_dir,
        run_id=sel_ns.run_id,
    )
    result = execute(mgr, REGISTRY, opts,
                     context_extra={"scopes": mgr.status()})
    doc = result.doc

    out = FLAGS.get("benchmark_out")
    if out:
        write_json(doc, out)
        log.info("wrote %s (%d records)", out, len(doc["benchmarks"]))
    else:
        write_json(doc, sys.stdout)
        print()

    rc = 0
    if sel_ns.baseline:
        comps = compare_documents(load_document(sel_ns.baseline), doc)
        print(format_comparisons(comps), file=sys.stderr)
        counts = summarize(comps)
        log.info("baseline diff: %s",
                 ", ".join(f"{v} {k}" for k, v in sorted(counts.items())))
        if gate_failures(comps):
            rc = 1
    if sel_ns.save_baseline:
        save_baseline(doc, sel_ns.save_baseline)
    return rc


if __name__ == "__main__":
    sys.exit(main())
