"""Open-loop arrival processes for serving benchmarks (jax-free).

Closed-loop load generators (send the next request when the previous
one returns) hide queueing: the generator slows down exactly when the
system does, so tail latency under overload is never exercised.  The
serve scope drives :class:`repro.serve.ServeEngine` with **open-loop**
traffic instead — requests arrive on a schedule that does not care how
the server is doing — which is the only way p99/p999 and goodput under
an SLO mean anything (the continuous-benchmarking frameworks in
PAPERS.md all gate on tail behaviour, not means).

Three generators, each returning a sorted list of arrival *offsets* in
seconds from the start of the window:

  * :func:`poisson` — homogeneous Poisson process (i.i.d. exponential
    inter-arrivals at ``rate`` req/s), the classic memoryless baseline;
  * :func:`bursty` — Markov-modulated on/off process: an "on" state
    arriving at ``burst_factor × rate`` alternates with a quiet "off"
    state at ``idle_factor × rate``, with exponentially-distributed
    sojourn times.  Mean rate ≈ the requested ``rate``; the variance is
    what stresses admission and queue depth;
  * :func:`diurnal` — inhomogeneous Poisson via thinning: the rate
    ramps sinusoidally between ``floor × rate`` and ``rate`` over one
    ``period`` (a compressed day), modelling the ramp-up/ramp-down
    shape production traffic actually has.

Determinism contract: every generator draws only from
``random.Random(seed)`` — the Mersenne-Twister stream is specified by
CPython, so a (kind, rate, n, seed) tuple replays **byte-identical**
traces across processes, machines and shard workers.  Nothing here
imports jax or numpy: the module must stay importable (and cheap) in
any worker, and traces must never depend on array-library versions.
"""
from __future__ import annotations

import math
import random
from typing import List

#: Generator names accepted by :func:`generate` (a serve-scope axis).
ARRIVAL_KINDS = ("poisson", "bursty", "diurnal")


def _check(rate: float, n: int) -> None:
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0 req/s (got {rate!r})")
    if n < 0:
        raise ValueError(f"arrival count must be >= 0 (got {n!r})")


def poisson(rate: float, n: int, seed: int = 0) -> List[float]:
    """``n`` arrival offsets of a Poisson process at ``rate`` req/s."""
    _check(rate, n)
    rng = random.Random(seed)
    t = 0.0
    out: List[float] = []
    for _ in range(n):
        t += rng.expovariate(rate)
        out.append(t)
    return out


def bursty(rate: float, n: int, seed: int = 0, *,
           burst_factor: float = 4.0, idle_factor: float = 0.25,
           mean_sojourn: float = 0.25) -> List[float]:
    """Markov-modulated on/off arrivals averaging ``rate`` req/s.

    Two states alternate with exponential sojourn times of mean
    ``mean_sojourn`` seconds: "on" arrives at ``burst_factor * rate``,
    "off" at ``idle_factor * rate``.  Inter-arrival draws use the
    current state's rate; a draw that overshoots the state's remaining
    sojourn rolls into the next state (re-drawn at the new rate from
    the leftover time's survival — memorylessness makes the simple
    re-draw exact).
    """
    _check(rate, n)
    if burst_factor <= 0 or idle_factor <= 0:
        raise ValueError("burst_factor and idle_factor must be > 0")
    rng = random.Random(seed)
    t = 0.0
    state_on = True
    state_end = rng.expovariate(1.0 / mean_sojourn)
    out: List[float] = []
    while len(out) < n:
        lam = rate * (burst_factor if state_on else idle_factor)
        gap = rng.expovariate(lam)
        if t + gap < state_end:
            t += gap
            out.append(t)
        else:
            # no arrival before the state flips: jump to the boundary
            # and restart the (memoryless) draw in the next state
            t = state_end
            state_on = not state_on
            state_end = t + rng.expovariate(1.0 / mean_sojourn)
    return out


def diurnal(rate: float, n: int, seed: int = 0, *,
            period: float = 2.0, floor: float = 0.2) -> List[float]:
    """Inhomogeneous Poisson arrivals with a sinusoidal daily ramp.

    The instantaneous rate is ``rate * (floor + (1-floor) *
    sin²(π t / period))`` — quiet at the window edges, peaking at
    ``rate`` mid-period — sampled exactly by Lewis-Shedler thinning
    against the ``rate`` envelope.
    """
    _check(rate, n)
    if not 0.0 < floor <= 1.0:
        raise ValueError(f"floor must be in (0, 1] (got {floor!r})")
    rng = random.Random(seed)
    t = 0.0
    out: List[float] = []
    while len(out) < n:
        t += rng.expovariate(rate)
        lam = floor + (1.0 - floor) * math.sin(math.pi * t / period) ** 2
        if rng.random() <= lam:
            out.append(t)
    return out


def generate(kind: str, rate: float, n: int, seed: int = 0) -> List[float]:
    """Dispatch on a generator name (the serve scope's ``arrival`` axis).

    Raises ``ValueError`` (with the available set) on an unknown kind —
    the same contract as ``validate_meter_name``.
    """
    if kind == "poisson":
        return poisson(rate, n, seed)
    if kind == "bursty":
        return bursty(rate, n, seed)
    if kind == "diurnal":
        return diurnal(rate, n, seed)
    raise ValueError(f"unknown arrival process {kind!r} "
                     f"(available: {', '.join(ARRIVAL_KINDS)})")
