"""Global benchmark registry — how benchmark code reaches the SCOPE binary.

In the paper, scopes register benchmarks through Google Benchmark's
``BENCHMARK()`` macro and the core binary links every object library into a
single executable.  Here, scopes register through :func:`register_benchmark`
(usually via the :func:`benchmark` decorator) and the registry is the link
step: one namespace, uniform filtering, uniform reporting.

Names are mangled ``<scope>/<family>`` so results are attributable to the
scope that produced them, mirroring SCOPE's per-scope name prefixes.
"""
from __future__ import annotations

import re
from typing import Callable, Dict, List, Optional, Sequence

from .benchmark import Benchmark, BenchmarkFn, _capture_source, match_params


class BenchmarkRegistry:
    def __init__(self) -> None:
        self._benchmarks: Dict[str, Benchmark] = {}

    def register(self, bench: Benchmark) -> Benchmark:
        if bench.name in self._benchmarks:
            raise ValueError(f"benchmark {bench.name!r} already registered")
        self._benchmarks[bench.name] = bench
        return bench

    def get(self, name: str) -> Benchmark:
        return self._benchmarks[name]

    def all(self) -> List[Benchmark]:
        return list(self._benchmarks.values())

    def filter(self, pattern: str = ".*",
               scopes: Optional[Sequence[str]] = None,
               params: Optional[Dict[str, List[str]]] = None
               ) -> List[Benchmark]:
        """Select benchmark families by name regex, owning scope, and/or
        a ``--param key=value`` predicate (family kept when *any* of its
        instances carries a matching parameter point)."""
        rx = re.compile(pattern)
        out = []
        for b in self._benchmarks.values():
            if scopes is not None and b.scope not in scopes:
                continue
            instances = b.instances()
            # match either the family name or any instance name
            if not (rx.search(b.name) or any(
                    rx.search(n) for n, _ in instances)):
                continue
            if params and not any(match_params(p, params)
                                  for _, p in instances):
                continue
            out.append(b)
        return out

    def remove_scope(self, scope: str) -> None:
        for name in [n for n, b in self._benchmarks.items()
                     if b.scope == scope]:
            del self._benchmarks[name]

    def reset(self) -> None:
        self._benchmarks.clear()

    def __len__(self) -> int:
        return len(self._benchmarks)

    def __contains__(self, name: str) -> bool:
        return name in self._benchmarks


REGISTRY = BenchmarkRegistry()


def register_benchmark(name: str, fn: BenchmarkFn, scope: str = "core",
                       registry: Optional[BenchmarkRegistry] = None,
                       **kwargs) -> Benchmark:
    """Imperative registration (GB ``RegisterBenchmark`` analogue)."""
    reg = registry if registry is not None else REGISTRY
    full = f"{scope}/{name}" if not name.startswith(scope + "/") else name
    bench = Benchmark(name=full, fn=fn, scope=scope, **kwargs)
    # capture the body's source now for the static-analysis pass
    # (repro.core.lint); None when inspect cannot see it
    bench.source, bench.source_file, bench.source_line = _capture_source(fn)
    return reg.register(bench)


def benchmark(name: Optional[str] = None, scope: str = "core",
              registry: Optional[BenchmarkRegistry] = None,
              **kwargs) -> Callable[[BenchmarkFn], Benchmark]:
    """Decorator registration (GB ``BENCHMARK()`` macro analogue).

    Returns the :class:`Benchmark` so callers can chain sweep builders::

        @benchmark(scope="example")
        def axpy(state):
            ...
        axpy.range_multiplier_args(1 << 10, 1 << 20)
    """
    def deco(fn: BenchmarkFn) -> Benchmark:
        bname = name or fn.__name__
        b = register_benchmark(bname, fn, scope=scope, registry=registry,
                               **kwargs)
        b.doc = (fn.__doc__ or "").strip()
        return b
    return deco
