"""Append-only run-history store — ``results/history.jsonl``.

One-shot runs answer "how fast is it now"; continuous benchmarking
(ROOT's continuous performance framework, exaCB's incremental
collections) needs "how fast has it *been*".  This module is that
memory: every merged run appends one JSON line per benchmark instance
to ``<results-dir>/history.jsonl``:

.. code-block:: json

    {"run_id": "20260731T120000-42", "ts": "2026-07-31T12:00:00",
     "name": "example/saxpy/n:256", "mean_s": 1.1e-05, "stddev_s": 0.0,
     "n": 1, "errors": 0, "sysinfo": "9f2b6c01d3e4",
     "verdict": "similar", "ratio": 0.98}

  * the orchestrator (:mod:`repro.core.orchestrate`) appends at merge
    time whenever a run persists to a results directory;
  * ``verdict`` is the instance's fate versus its *previous* history
    record (``new`` / ``similar`` / ``improvement`` / ``regression`` /
    ``errored``), so the file is a readable changelog on its own;
  * ``counters`` (when present) carries the mean of every inlined GB
    counter on the instance's records — meter metrics (``flops``,
    ``flops_per_second``, docs/measurement.md) and body counters alike
    survive into the store (:func:`doc_counters`);
  * ``sysinfo`` is :func:`repro.core.sysinfo.context_digest` of the
    run's context — records from different machines/stacks are never
    compared or pooled: verdicts only look at same-digest predecessors,
    and windowed queries fold only the newest digest's records;
  * :func:`window_document` folds the last N runs per benchmark into a
    synthetic GB-JSON document whose "repetitions" are the per-run
    means.  :func:`repro.core.baseline.load_document` loads any
    ``*.jsonl`` path through it, so ``python -m repro run --baseline
    results/history.jsonl`` (or ``compare results/history.jsonl
    results/<run-id>``) gates against the *windowed* history — the
    pooled cross-run stddev catches slow drifts that single-run compare
    calls "similar" at every step;
  * :func:`detect_drift` is that same query as an API: latest run
    versus the window of runs before it.

The file is append-only JSONL on purpose: a crashed writer can at worst
leave one torn final line (readers skip it), and two sequential runs
never rewrite each other's records.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .logging import get_logger
from .sysinfo import context_digest

log = get_logger("history")

HISTORY_FILE = "history.jsonl"

#: Default number of prior runs pooled for windowed comparisons.
DEFAULT_WINDOW = 5

# verdict values (superset of baseline's: adds NEW/ERRORED)
NEW = "new"
ERRORED = "errored"

Record = Dict[str, Any]


def history_path(results_dir: str) -> str:
    return os.path.join(results_dir, HISTORY_FILE)


def iter_lines(path: str):
    """Yield ``(line text, record)`` for every valid record line.

    The file is read as *bytes* and decoded per line: a fleet writer
    killed mid-append can tear a line anywhere — including inside a
    multi-byte UTF-8 sequence — and one torn tail must not poison every
    later query or index build.  Undecodable, unparseable and non-record
    lines are warned about and skipped, never raised.
    """
    with open(path, "rb") as f:
        for lineno, raw in enumerate(f, 1):
            try:
                line = raw.decode("utf-8").strip()
            except UnicodeDecodeError:
                log.warning("%s:%d: skipping undecodable history line",
                            path, lineno)
                continue
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                log.warning("%s:%d: skipping unparseable history line",
                            path, lineno)
                continue
            if isinstance(rec, dict) and "name" in rec:
                yield line, rec


def scan_history(path: str) -> List[Record]:
    """Direct linear scan of a history file — torn/garbage lines are
    skipped, not fatal.  This is the reference semantics the store
    index (:mod:`repro.store.index`) must reproduce exactly."""
    return [rec for _line, rec in iter_lines(path)]


def load_history(path: str, store: bool = True) -> List[Record]:
    """Read a history file; a torn/garbage line is skipped, not fatal.

    When an SQLite index (``history.db``, :mod:`repro.store.index`)
    sits next to the file, records come from it after a cheap
    watermark refresh instead of a full re-parse — the store-backed
    fast path behind ``compare --baseline results/history.jsonl``,
    drift gating and the report's trend pages.  Any index problem
    falls back to the direct scan (``store=False`` forces it); both
    paths return identical records by construction.
    """
    if store:
        records = _store_records(path)
        if records is not None:
            return records
    return scan_history(path)


def _store_records(path: str) -> Optional[List[Record]]:
    """Records via the SQLite index, or None when there is no usable
    index for ``path`` (no db next to it, stale, or unreadable)."""
    if not path.endswith(".jsonl") or not os.path.exists(path):
        return None
    from repro.store.index import StoreStale, db_path, load_records
    if not os.path.exists(db_path(path)):
        return None
    try:
        return load_records(path)
    except StoreStale as e:
        log.warning("store index unusable (%s); scanning %s directly",
                    e, path)
    except Exception as e:  # noqa: BLE001 - a broken index must never
        # break a read; the JSONL is the source of truth
        log.warning("store index broken (%r); scanning %s directly",
                    e, path)
    return None


def run_ids(records: Iterable[Record]) -> List[str]:
    """Distinct run IDs in append (chronological) order."""
    out: List[str] = []
    for r in records:
        rid = r.get("run_id", "")
        if rid and rid not in out:
            out.append(rid)
    return out


def for_run(records: Iterable[Record], run_id: str) -> List[Record]:
    return [r for r in records if r.get("run_id") == run_id]


def series(records: Iterable[Record], name: str) -> List[Record]:
    """All records of one benchmark instance, in append order."""
    return [r for r in records if r.get("name") == name]


def benchmark_names(records: Iterable[Record]) -> List[str]:
    """Distinct benchmark names in first-seen order."""
    out: List[str] = []
    for r in records:
        n = r.get("name", "")
        if n and n not in out:
            out.append(n)
    return out


def doc_counters(doc: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Mean numeric counters per ``run_name`` of a merged document.

    GB inlines counters at the record's top level, so a counter is any
    numeric field that is not a canonical record key — which is exactly
    how meter metrics (``flops``, ``flops_per_second``, ...) and body
    counters reach history.  Iteration records are averaged; a name
    reduced to aggregates by ``--aggregates-only`` falls back to its
    ``mean`` aggregate's counters.
    """
    from .runner import RESERVED_RECORD_KEYS
    sums: Dict[str, Dict[str, float]] = {}
    counts: Dict[str, Dict[str, int]] = {}
    agg: Dict[str, Dict[str, float]] = {}
    for rec in doc.get("benchmarks", []):
        if rec.get("error_occurred") or rec.get("skipped"):
            continue
        name = rec.get("run_name") or rec.get("name", "")
        extras = {k: float(v) for k, v in rec.items()
                  if k not in RESERVED_RECORD_KEYS
                  and isinstance(v, (int, float))
                  and not isinstance(v, bool)}
        if not extras:
            continue
        if rec.get("run_type") == "aggregate":
            if rec.get("aggregate_name") == "mean":
                agg[name] = extras
            continue
        s = sums.setdefault(name, {})
        c = counts.setdefault(name, {})
        for k, v in extras.items():
            s[k] = s.get(k, 0.0) + v
            c[k] = c.get(k, 0) + 1
    out: Dict[str, Dict[str, float]] = {}
    for name, s in sums.items():
        out[name] = {k: v / counts[name][k] for k, v in s.items()}
    for name, extras in agg.items():
        out.setdefault(name, extras)
    return out


def _cached_names(doc: Dict[str, Any]) -> set:
    """Instance names whose every document record was materialized from
    history (``cached: true``) rather than measured — a delta run's
    skipped instances (repro.core.fingerprint)."""
    measured, cached = set(), set()
    for rec in doc.get("benchmarks", []):
        name = rec.get("run_name") or rec.get("name", "")
        if not name:
            continue
        (cached if rec.get("cached") else measured).add(name)
    return cached - measured


def _verdict(prev: Optional[Record], mean: Optional[float],
             stddev: float, n: int, threshold: float, sigmas: float
             ) -> Tuple[str, Optional[float]]:
    """Verdict + ratio of a fresh measurement vs its previous record.

    Mirrors :func:`repro.core.baseline.compare_documents` semantics: the
    relative change must clear ``threshold`` AND — only when *both*
    sides carry repetition data (n > 1) — the mean shift must clear
    ``sigmas`` pooled standard deviations.  A single-shot measurement
    has no noise estimate, so the ratio alone decides, exactly as in
    ``compare_documents``.
    """
    from .baseline import IMPROVEMENT, REGRESSION, SIMILAR
    if mean is None:
        return ERRORED, None
    if prev is None or prev.get("mean_s") is None:
        return NEW, None
    pm = float(prev["mean_s"])
    if pm <= 0:
        return NEW, None
    ratio = mean / pm
    rel = (mean - pm) / pm
    pooled = math.sqrt(float(prev.get("stddev_s") or 0.0) ** 2
                       + stddev ** 2)
    prev_n = int(prev.get("n") or 0)
    if prev_n > 1 and n > 1 and pooled > 0:
        significant = abs(mean - pm) > sigmas * pooled
    else:
        significant = True
    if significant and rel > threshold:
        return REGRESSION, ratio
    if significant and rel < -threshold:
        return IMPROVEMENT, ratio
    return SIMILAR, ratio


def append_run(results_dir: str, doc: Dict[str, Any],
               run_id: Optional[str] = None,
               threshold: float = 0.10, sigmas: float = 2.0,
               tag: Optional[str] = None) -> List[Record]:
    """Append one record per benchmark instance of a merged document.

    Returns the appended records ([] when the run is already recorded —
    a resumed run merges twice but must not double-append).  ``ts`` and
    the sysinfo digest come from the document's own context, so history
    records stay reproducible from the run artifacts.  ``tag`` marks
    what produced the run (e.g. ``"tune"`` for autotuning trials) so
    consumers can tell trial records from ordinary benchmark runs.
    """
    from .baseline import collect_stats
    ctx = doc.get("context", {})
    run_id = run_id or ctx.get("run_id") or "run"
    fingerprints = ctx.get("fingerprints") or {}
    cached_names = _cached_names(doc)
    path = history_path(results_dir)
    prior: List[Record] = []
    if os.path.exists(path):
        prior = load_history(path)
        if any(r.get("run_id") == run_id for r in prior):
            log.info("history already has run %s; not appending", run_id)
            return []
    ts = ctx.get("date", "")
    digest = context_digest(ctx)
    # verdicts only ever compare same-digest records: a record produced
    # on a different machine/stack is not a valid "previous" — the new
    # environment starts its own series ("new")
    last: Dict[str, Record] = {}
    for r in prior:
        if r.get("sysinfo") == digest and not r.get("cached"):
            last[r.get("name", "")] = r

    counters = doc_counters(doc)
    records: List[Record] = []
    for name, st in collect_stats(doc).items():
        mean = st.mean if st.has_times else None
        stddev = st.stddev if st.has_times else 0.0
        verdict, ratio = _verdict(last.get(name), mean, stddev, st.n,
                                  threshold, sigmas)
        rec: Record = {
            "run_id": run_id, "ts": ts, "name": name,
            "mean_s": mean, "stddev_s": stddev, "n": st.n,
            "errors": st.errors, "sysinfo": digest, "verdict": verdict,
        }
        if tag:
            rec["tag"] = tag
        if name in fingerprints:
            rec["fingerprint"] = fingerprints[name]
        if name in cached_names:
            # a replayed (delta-skipped) instance: its mean is an echo of
            # an older run, not a new measurement — drift pooling and
            # delta freshness both ignore it
            rec["cached"] = True
        if ratio is not None:
            rec["ratio"] = round(ratio, 6)
        if name in counters:
            rec["counters"] = counters[name]
        records.append(rec)
    if not records:
        return []
    os.makedirs(results_dir, exist_ok=True)
    with open(path, "a") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
    log.info("history: appended %d record(s) for run %s to %s",
             len(records), run_id, path)
    return records


# ---------------------------------------------------------------------------
# windowed queries (what single-run compare misses)
# ---------------------------------------------------------------------------

def window_document(source: Union[str, Sequence[Record]],
                    window: int = DEFAULT_WINDOW,
                    sysinfo: Optional[str] = None) -> Dict[str, Any]:
    """Fold the last ``window`` runs per benchmark into a GB-JSON doc.

    Each benchmark's recent per-run means become repetition records, so
    :func:`repro.core.baseline.compare_documents` pools them into a
    cross-run mean *and stddev* — the windowed baseline a drifting
    benchmark is judged against.  ``source`` is a ``history.jsonl`` path
    or an already-loaded record list.

    Only records from one machine/stack configuration are folded:
    ``sysinfo`` selects the digest (default: the digest of the newest
    record), so a history shared across machines never pools
    incomparable numbers into one baseline.  Replayed ``cached`` records
    (a delta run's skipped instances) are excluded — pooling the same
    mean twice would deflate the cross-run stddev and make the window
    look artificially stable.
    """
    records = load_history(source) if isinstance(source, str) \
        else list(source)
    records = [r for r in records if not r.get("cached")]
    if sysinfo is None and records:
        sysinfo = records[-1].get("sysinfo")
    if sysinfo is not None:
        records = [r for r in records if r.get("sysinfo") == sysinfo]
    benchmarks: List[Dict[str, Any]] = []
    for name in benchmark_names(records):
        recent = [r for r in series(records, name)
                  if r.get("mean_s") is not None][-max(1, window):]
        for i, r in enumerate(recent):
            benchmarks.append({
                "name": name, "run_name": name, "run_type": "iteration",
                "repetitions": len(recent), "repetition_index": i,
                "threads": 1, "iterations": 1,
                "real_time": float(r["mean_s"]),
                "cpu_time": float(r["mean_s"]),
                "time_unit": "s",
                "history_run_id": r.get("run_id", ""),
            })
    src = source if isinstance(source, str) else "<records>"
    return {"context": {"history_source": src, "history_window": window,
                        "history_sysinfo": sysinfo},
            "benchmarks": benchmarks}


def detect_drift(records: Sequence[Record], window: int = DEFAULT_WINDOW,
                 threshold: float = 0.10, sigmas: float = 2.0):
    """Latest run vs the window of runs before it.

    Returns :class:`repro.core.baseline.Comparison` objects — the same
    verdicts ``python -m repro compare`` prints — computed against the
    pooled window, which flags slow drifts where every consecutive pair
    of runs looked "similar".  Empty when history holds fewer than two
    runs.  Prior runs from a different machine/stack (sysinfo digest)
    than the latest run are excluded from the window.

    ``cached`` records are no-ops on both sides: a delta run
    (``--since`` / ``repro ci``) re-measures only changed instances, so
    drift is judged exactly on those — replayed records neither trigger
    verdicts nor count skipped instances as "removed".
    """
    from .baseline import compare_documents
    ids = run_ids(records)
    if len(ids) < 2:
        return []
    latest = ids[-1]
    all_latest = for_run(records, latest)
    latest_records = [r for r in all_latest if not r.get("cached")]
    if not latest_records:
        return []                     # fully-cached run: nothing new
    digest = latest_records[-1].get("sysinfo")
    prior = [r for r in records if r.get("run_id") != latest]
    if len(latest_records) < len(all_latest):
        # a delta run: judge only what was re-measured — skipped
        # instances are vouched for by their cached records, not missing
        fresh_names = {r.get("name") for r in latest_records}
        prior = [r for r in prior if r.get("name") in fresh_names]
    base = window_document(prior, window, sysinfo=digest)
    contender = window_document(latest_records, window=1, sysinfo=digest)
    return compare_documents(base, contender,
                             threshold=threshold, sigmas=sigmas)
