"""System characterization context — what SCOPE puts in the JSON ``context``.

Google Benchmark emits a ``context`` block (date, host, cpu info, build
type).  We extend it with the JAX/TPU-stack facts that matter for systems
characterization: backend, device kinds/counts, mesh shape if active, jax &
jaxlib versions, and relevant XLA flags.  This block is what makes two
benchmark JSON files comparable across systems — the heart of SCOPE's
portability story.
"""
from __future__ import annotations

import datetime
import hashlib
import json
import os
import platform
import types
from typing import Any, Dict, Optional

# Target-hardware constants (TPU v5e) used by the modeled scopes & roofline.
# Immutable on purpose: benchmark bodies read these at call time, and the
# instance fingerprint (repro.core.fingerprint) only hashes source — a
# mutable table here could change measurements without changing digests
# (the SCOPE110 hazard).
TPU_V5E = types.MappingProxyType({
    "name": "tpu_v5e",
    "peak_bf16_flops": 197e12,     # FLOP/s per chip
    "hbm_bandwidth": 819e9,        # B/s per chip
    "ici_link_bandwidth": 50e9,    # B/s per link (~50 GB/s/link)
    "ici_links_per_chip": 4,       # 2D torus: +x, -x, +y, -y
    "hbm_bytes": 16 * 2 ** 30,     # 16 GiB HBM per chip
    "vmem_bytes": 128 * 2 ** 20,   # ~128 MiB VMEM per core
    "mxu_shape": (128, 128),       # systolic array tile
    "dcn_bandwidth": 25e9,         # B/s per host cross-pod (modeled)
})


def _cpu_info() -> Dict[str, Any]:
    info: Dict[str, Any] = {
        "machine": platform.machine(),
        "processor": platform.processor() or "unknown",
        "num_cpus": os.cpu_count() or 1,
    }
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    info["model_name"] = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return info


def _jax_info() -> Dict[str, Any]:
    try:
        import jax
        devs = jax.devices()
        return {
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": len(devs),
            "device_kind": devs[0].device_kind if devs else "none",
        }
    except Exception as e:  # pragma: no cover - jax import failure
        return {"jax_version": "unavailable", "error": str(e)}


# Context keys that determine whether two runs are comparable: the
# machine, the accelerator stack, and the XLA configuration — NOT the
# date/run-id, which differ on every run by construction.
_DIGEST_KEYS = (
    "host_name", "machine", "processor", "num_cpus", "model_name",
    "jax_version", "backend", "device_count", "device_kind",
    "xla_flags", "target_hardware", "scope_version",
)


def context_digest(ctx: Dict[str, Any]) -> str:
    """Short stable digest of a context's comparability-relevant facts.

    Two runs with the same digest were produced by the same
    host/accelerator-stack configuration; run-history records carry it
    so cross-machine records are visibly not comparable.
    """
    facts = {k: ctx.get(k) for k in _DIGEST_KEYS}
    blob = json.dumps(facts, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode("utf-8")).hexdigest()[:12]


def build_context(extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The ``context`` object written at the top of every result JSON."""
    ctx: Dict[str, Any] = {
        "date": datetime.datetime.now().isoformat(timespec="seconds"),
        "host_name": platform.node(),
        "executable": "scope",
        "scope_version": "1.0.0-jax",
        "library_build_type": "release",
        "caches": [],
        **_cpu_info(),
        **_jax_info(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "target_hardware": TPU_V5E["name"],
    }
    if extra:
        ctx.update(extra)
    return ctx
