"""Search strategies over a :class:`ParamSpace` (``python -m repro tune``).

Exhaustive sweeps don't scale to kernel block spaces (3 axes × 4 values
is already 64 compile-and-measure trials), so the tuner explores under a
hard trial *budget* with pluggable strategies:

  * **factorial screening** — a coarse pass over the space's center
    point plus each axis's extremes.  Cheap (1 + 2·axes trials) and it
    yields an axis-*sensitivity* ranking: how much the objective swings
    when one axis moves across its range with the others held at center.
  * **greedy hill-climb** — seeded, deterministic neighbor moves (±1
    step along one axis's sorted values) from the best screened configs;
    moves only on strict improvement, so it terminates without cycling.
  * **Pareto-frontier extraction** — the non-dominated trials across
    several objectives (e.g. ``real_time_s`` vs ``flops_per_second``
    from the cost-model meter).

Everything is deterministic for a given ``(space, strategy, budget,
seed)``: candidate enumeration is sorted, the only randomness is a
``random.Random(seed)`` shuffle of neighbor *evaluation order*, and
already-evaluated configs are served from a cache without consuming
budget.  Objectives are minimized unless the metric name ends in
``_per_second`` (a rate — maximized).  The module is jax-free: the
evaluate callable owns all measurement.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from .benchmark import Params, ParamSpace

#: ``--strategy`` choices; ``auto`` = screening then hill-climb.
STRATEGIES = ("auto", "screening", "hillclimb")

_INF = float("inf")


class TrialError(RuntimeError):
    """Raised by an evaluate callable when one trial fails (bad config,
    runtime error).  The failure is recorded — it still consumes budget
    — and the search moves on."""


def lower_is_better(objective: str) -> bool:
    """Orientation: rates (``*_per_second``) are maximized, everything
    else (times, bytes, footprints) minimized."""
    return not objective.endswith("_per_second")


@dataclass(frozen=True)
class Trial:
    """One evaluated configuration."""

    index: int                      # evaluation order, 0-based
    phase: str                      # "screen" | "climb"
    params: Params
    metrics: Mapping[str, float] = field(default_factory=dict)
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None

    def to_json(self) -> Dict[str, Any]:
        return {"index": self.index, "phase": self.phase,
                "params": dict(self.params),
                "metrics": dict(self.metrics),
                **({"error": self.error} if self.error else {})}


def oriented(objective: str, trial: Trial) -> float:
    """The trial's objective as a minimize-me score (+inf when failed
    or the metric is missing)."""
    if not trial.ok or objective not in trial.metrics:
        return _INF
    value = float(trial.metrics[objective])
    return value if lower_is_better(objective) else -value


def pareto_front(trials: Sequence[Trial],
                 objectives: Sequence[str]) -> List[Trial]:
    """Non-dominated trials (orientation-aware), in evaluation order.
    Trials missing any objective are excluded."""
    scored = [(t, [oriented(o, t) for o in objectives]) for t in trials
              if t.ok and all(o in t.metrics for o in objectives)]
    front = []
    for t, s in scored:
        dominated = any(
            all(u_i <= s_i for u_i, s_i in zip(u, s)) and u != s
            for _, u in scored)
        if not dominated:
            front.append(t)
    return front


@dataclass
class SearchResult:
    objective: str
    strategy: str
    budget: int
    seed: int
    trials: List[Trial]
    best: Optional[Trial]
    baseline: Optional[Trial]            # the builtin-default config, if run
    sensitivity: List[Tuple[str, float]]  # axis → objective span, ranked
    frontier: List[Trial]
    exhausted: bool                       # budget ran out with work left

    def to_json(self) -> Dict[str, Any]:
        return {
            "objective": self.objective, "strategy": self.strategy,
            "budget": self.budget, "seed": self.seed,
            "trials": [t.to_json() for t in self.trials],
            "best": self.best.to_json() if self.best else None,
            "baseline": self.baseline.to_json() if self.baseline else None,
            "sensitivity": [{"axis": a, "span": s}
                            for a, s in self.sensitivity],
            "frontier": [t.index for t in self.frontier],
            "exhausted": self.exhausted,
        }


def _axis_values(space: ParamSpace) -> Dict[str, List[Any]]:
    """Sorted distinct values per axis (mixed-type safe)."""
    values: Dict[str, List[Any]] = {}
    for axis in space.axes():
        seen = {p[axis] for p in space.points() if axis in p}
        values[axis] = sorted(seen, key=lambda v: (str(type(v)), v))
    return values


class SearchSession:
    """Shared trial bookkeeping: the budgeted, cached evaluate loop."""

    def __init__(self, space: ParamSpace,
                 evaluate: Callable[[Params], Mapping[str, float]],
                 objective: str, budget: int,
                 cost_hint: Optional[Callable[[Params],
                                              Optional[float]]] = None):
        if not len(space):
            raise ValueError("cannot search an empty ParamSpace")
        self.space = space
        self.objective = objective
        self.budget = budget
        self._evaluate = evaluate
        self._cost_hint = cost_hint
        self._members = {p.canonical(): p for p in space.points()}
        self.values = _axis_values(space)
        self.trials: List[Trial] = []
        self._by_key: Dict[str, Trial] = {}
        self.truncated = False      # a candidate was dropped for budget

    # -- membership / budget -----------------------------------------
    def contains(self, params: Params) -> bool:
        return params.canonical() in self._members

    def cached(self, params: Params) -> bool:
        return params.canonical() in self._by_key

    @property
    def spent(self) -> int:
        return len(self.trials)

    @property
    def remaining(self) -> int:
        return max(0, self.budget - self.spent)

    # -- evaluation ----------------------------------------------------
    def run(self, params: Params, phase: str) -> Optional[Trial]:
        """Evaluate ``params`` (or serve the cached trial — free).
        Returns None when the budget is spent."""
        key = params.canonical()
        if key in self._by_key:
            return self._by_key[key]
        if self.remaining <= 0:
            self.truncated = True
            return None
        try:
            metrics = dict(self._evaluate(params))
            trial = Trial(index=len(self.trials), phase=phase,
                          params=params, metrics=metrics)
        except TrialError as e:
            trial = Trial(index=len(self.trials), phase=phase,
                          params=params, error=str(e))
        self.trials.append(trial)
        self._by_key[key] = trial
        return trial

    def score(self, trial: Optional[Trial]) -> float:
        if trial is None:
            return _INF
        return oriented(self.objective, trial)

    def order_by_cost(self, candidates: List[Params]) -> List[Params]:
        """Cheapest-hinted first (stable: unhinted keep their order,
        after the hinted) — how ``--costs`` steers the budget."""
        if self._cost_hint is None:
            return candidates
        hints = [self._cost_hint(c) for c in candidates]
        return [c for _, c in sorted(
            zip(hints, candidates),
            key=lambda hc: hc[0] if hc[0] is not None else _INF)]

    def best(self) -> Optional[Trial]:
        finite = [t for t in self.trials if self.score(t) < _INF]
        if not finite:
            return None
        return min(finite, key=lambda t: (self.score(t), t.index))


def screening_plan(space: ParamSpace) -> List[Tuple[str, Params]]:
    """The factorial-screening candidates as ``(label, params)``:
    the center point first (label ``"center"``), then each axis's
    extreme variants (labeled by axis).  Variants pruned out of the
    space by constraints are skipped."""
    values = _axis_values(space)
    members = {p.canonical(): p for p in space.points()}
    center_map = {a: vals[(len(vals) - 1) // 2] for a, vals in
                  values.items()}
    center = Params(center_map)
    if center.canonical() not in members:
        # constraints pruned the geometric center — anchor on the first
        # point of the space instead (deterministic)
        center = space.points()[0]
    plan = [("center", center)]
    seen = {center.canonical()}
    for axis, vals in values.items():
        for v in (vals[0], vals[-1]):
            cand = Params({**dict(center), axis: v})
            key = cand.canonical()
            if key in members and key not in seen:
                plan.append((axis, cand))
                seen.add(key)
    return plan


def _screen(session: SearchSession) -> List[Tuple[str, float]]:
    """Run the screening plan; returns the sensitivity ranking (axis →
    oriented-objective span over that axis's variants + center)."""
    plan = screening_plan(session.space)
    center_trial = session.run(plan[0][1], "screen")
    variants = session.order_by_cost([p for _, p in plan[1:]])
    label_of = {p.canonical(): label for label, p in plan}
    trials_by_axis: Dict[str, List[Trial]] = {}
    for cand in variants:
        t = session.run(cand, "screen")
        if t is not None:
            trials_by_axis.setdefault(label_of[cand.canonical()],
                                      []).append(t)
    sensitivity = []
    for axis in session.space.axes():
        scores = [session.score(t)
                  for t in trials_by_axis.get(axis, []) + (
                      [center_trial] if center_trial else [])]
        finite = [s for s in scores if s < _INF]
        span = (max(finite) - min(finite)) if len(finite) > 1 else 0.0
        sensitivity.append((axis, span))
    sensitivity.sort(key=lambda kv: -kv[1])
    return sensitivity


def _neighbors(session: SearchSession, current: Params) -> List[Params]:
    """In-space configs one step away along one axis's sorted values."""
    out = []
    for axis, vals in session.values.items():
        if axis not in current:
            continue
        i = vals.index(current[axis])
        for j in (i - 1, i + 1):
            if 0 <= j < len(vals):
                cand = Params({**dict(current), axis: vals[j]})
                if session.contains(cand):
                    out.append(cand)
    return out


def _hill_climb(session: SearchSession, start: Params,
                rng: random.Random) -> None:
    """Steepest-descent neighbor moves from ``start``; strict
    improvement only, so it cannot cycle."""
    current = session.run(start, "climb")
    if current is None or session.score(current) == _INF:
        return
    while True:
        candidates = _neighbors(session, current.params)
        # seeded shuffle decides which equal-cost neighbor is tried
        # first when the budget can't cover them all ...
        rng.shuffle(candidates)
        # ... and cost hints (stable sort) still put cheap ones first
        candidates = session.order_by_cost(candidates)
        evaluated = [t for t in (session.run(c, "climb")
                                 for c in candidates) if t is not None]
        if not evaluated:
            break
        best = min(evaluated, key=lambda t: (session.score(t), t.index))
        if session.score(best) < session.score(current):
            current = best
        else:
            break
        if session.remaining <= 0:
            break


def run_search(space: ParamSpace,
               evaluate: Callable[[Params], Mapping[str, float]],
               *, objective: str = "real_time_s", strategy: str = "auto",
               budget: int = 16, seed: int = 0,
               cost_hint: Optional[Callable[[Params],
                                            Optional[float]]] = None,
               baseline: Optional[Params] = None,
               frontier_objectives: Optional[Sequence[str]] = None,
               top_k: int = 2) -> SearchResult:
    """Search ``space`` for the config minimizing (or maximizing, for
    rates) ``objective`` under a hard ``budget`` of evaluations.

    ``baseline`` (e.g. the builtin default config) is evaluated first
    when given and it lies in the space — it anchors the speedup
    report but otherwise competes like any trial.  ``cost_hint(params)
    -> seconds|None`` steers evaluation order toward cheap configs.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r} "
                         f"(choices: {', '.join(STRATEGIES)})")
    if budget < 1:
        raise ValueError("budget must be >= 1")
    session = SearchSession(space, evaluate, objective, budget,
                            cost_hint=cost_hint)
    rng = random.Random(seed)

    baseline_trial = None
    if baseline is not None and session.contains(baseline):
        baseline_trial = session.run(baseline, "screen")

    sensitivity: List[Tuple[str, float]] = []
    if strategy in ("auto", "screening"):
        sensitivity = _screen(session)
    if strategy in ("auto", "hillclimb"):
        if session.trials:
            ranked = sorted(
                (t for t in session.trials if session.score(t) < _INF),
                key=lambda t: (session.score(t), t.index))
            seeds = [t.params for t in ranked[:top_k]]
        else:
            seeds = [screening_plan(space)[0][1]]
        for start in seeds:
            if session.remaining <= 0 and not session.cached(start):
                break
            _hill_climb(session, start, rng)

    objectives = list(frontier_objectives or [])
    if not objectives:
        objectives = [objective]
        for extra in ("flops_per_second",):
            if extra != objective and any(
                    extra in t.metrics for t in session.trials if t.ok):
                objectives.append(extra)
    return SearchResult(
        objective=objective, strategy=strategy, budget=budget, seed=seed,
        trials=session.trials, best=session.best(),
        baseline=baseline_trial, sensitivity=sensitivity,
        frontier=pareto_front(session.trials, objectives),
        exhausted=session.truncated,
    )
