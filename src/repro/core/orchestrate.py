"""Run-orchestration subsystem — parallel, failure-isolated scope execution.

This is the run stage of the SCOPE binary (paper Fig. 2(d)) rebuilt as an
orchestrator instead of a sequential loop.  The paper's design goal —
independently-developed scopes share one portable harness — extends
naturally to execution: scopes share *nothing* at run time, so each enabled
scope becomes one schedulable unit of work:

  * **parallelism** — scopes run in a process pool (``--jobs N``); each
    worker is a fresh interpreter (spawn) with its own registry/flags, so
    parallel scopes cannot contend on the global registry or JAX state;
  * **failure isolation** — a scope that *errors* produces an error shard;
    a scope that *kills its interpreter* (segfault, ``os._exit``) breaks
    only its worker: the orchestrator retries interpreter-killing scopes
    in standalone subprocesses (``python -m repro.core.orchestrate
    --worker``) and degrades them to error shards if they die again;
  * **streaming shards** — every scope yields a self-contained
    Google-Benchmark JSON document (a *shard*); shards are persisted under
    ``results/<run-id>/<scope>.json`` as they complete and merged into one
    schema-identical document (``merged.json``) at the end, so a crash
    mid-run loses only the unfinished scopes;
  * **baseline diffing** — the merged document is what
    :mod:`repro.core.baseline` stores and compares (``python -m repro
    compare A.json B.json``).

The merged document keeps the exact ``{"context", "benchmarks"}`` schema
:func:`repro.core.runner.run_benchmarks` emits — per-shard provenance is
tucked inside ``context["shards"]`` so any Google-Benchmark-compatible
consumer (ScopePlot included) reads merged output unchanged.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import subprocess
import sys
import tempfile
import time
import traceback
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor, as_completed
from concurrent.futures.process import BrokenProcessPool
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .logging import get_logger
from .runner import RunOptions, run_benchmarks, write_json
from .sysinfo import build_context

log = get_logger("orchestrate")

# Shard status values.
OK = "ok"            # scope ran; doc holds its records (may include errors)
ERROR = "error"      # scope failed to import/register/run; no records
CRASHED = "crashed"  # scope killed its interpreter(s); no records


def _spawn_safe_main() -> bool:
    main = sys.modules.get("__main__")
    if getattr(main, "__spec__", None) is not None:   # python -m …
        return True
    path = getattr(main, "__file__", None)
    return bool(path and os.path.exists(path))


@dataclass
class OrchestratorOptions:
    """How to schedule the enabled scopes."""

    jobs: int = 1                   # worker parallelism (1 → inline)
    isolate: str = "auto"           # auto | inline | pool | subprocess
    benchmark_filter: str = ".*"
    run: RunOptions = field(default_factory=RunOptions)
    # parsed flag values forwarded to workers (scopes read global FLAGS)
    flag_values: Dict[str, Any] = field(default_factory=dict)
    results_dir: Optional[str] = None   # persist shards+merged when set
    run_id: Optional[str] = None        # defaults to a timestamp
    subprocess_timeout: float = 1800.0

    def mode(self) -> str:
        if self.isolate != "auto":
            return self.isolate
        if self.jobs <= 1:
            return "inline"
        # spawn re-executes __main__; a parent without a real main module
        # (stdin, embedded interpreter) would break every pool worker at
        # startup, so fall straight to standalone subprocesses there.
        return "pool" if _spawn_safe_main() else "subprocess"


@dataclass
class ScopeShard:
    """One scope's contribution to a run."""

    scope: str
    module: str
    status: str = OK
    doc: Optional[Dict[str, Any]] = None   # GB-JSON document when status==OK
    error: str = ""
    duration_s: float = 0.0

    def meta(self) -> Dict[str, Any]:
        m: Dict[str, Any] = {"scope": self.scope, "module": self.module,
                             "status": self.status,
                             "duration_s": round(self.duration_s, 6)}
        if self.error:
            m["error"] = self.error
        return m


@dataclass
class RunResult:
    """Merged document + per-scope shards, as returned by :func:`execute`."""

    doc: Dict[str, Any]
    shards: List[ScopeShard]
    run_id: str
    out_dir: Optional[str] = None

    def shard(self, scope: str) -> Optional[ScopeShard]:
        for s in self.shards:
            if s.scope == scope:
                return s
        return None


# ---------------------------------------------------------------------------
# worker (runs in a fresh interpreter under pool/subprocess isolation)
# ---------------------------------------------------------------------------

def run_one_scope(module: str, run_opts: RunOptions, benchmark_filter: str,
                  flag_values: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    """Load ONE scope module and run its benchmarks; return the GB-JSON doc.

    Top-level (picklable) so it can be dispatched to a spawn-context
    process pool.  Uses the process-global registry/flags/hooks because
    scope bodies read them (e.g. ``FLAGS.get("example/greet")``) — under
    pool/subprocess isolation the process is fresh, so this *is* a clean
    slate; callers running inline should prefer :func:`execute`.
    """
    from .flags import FLAGS
    from .hooks import HOOKS
    from .registry import REGISTRY
    from .scope import ScopeManager

    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load([module])
    loaded = mgr.scopes()[0]
    if not loaded.available:
        raise RuntimeError(f"scope module {module} failed to import:\n"
                           f"{loaded.error}")
    for name, value in (flag_values or {}).items():
        FLAGS.set(name, value)
    rc = HOOKS.run_pre_parse()
    if rc is None:
        rc = HOOKS.run_post_parse()
    if rc is not None:
        raise RuntimeError(f"scope {loaded.scope.name} init hook requested "
                           f"exit ({rc})")
    mgr.register_all()
    if not loaded.available:
        raise RuntimeError(f"scope {loaded.scope.name} registration "
                           f"failed:\n{loaded.error}")
    benches = REGISTRY.filter(benchmark_filter,
                              scopes=[loaded.scope.name])
    return run_benchmarks(benches, run_opts,
                          context_extra={"scope": loaded.scope.name},
                          progress=False)


def _pool_worker(module: str, run_opts_dict: Dict[str, Any],
                 benchmark_filter: str, flag_values: Dict[str, Any]
                 ) -> Tuple[Dict[str, Any], float]:
    """Returns (doc, runtime) — timed in the worker, excluding queue wait."""
    t0 = time.perf_counter()
    doc = run_one_scope(module, RunOptions(**run_opts_dict),
                        benchmark_filter, flag_values)
    return doc, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# execution strategies
# ---------------------------------------------------------------------------

def _run_inline(name: str, module: str, registry, opts: OrchestratorOptions
                ) -> ScopeShard:
    """Run a scope in-process against the parent's already-built registry."""
    t0 = time.perf_counter()
    try:
        benches = registry.filter(opts.benchmark_filter, scopes=[name])
        doc = run_benchmarks(benches, opts.run,
                             context_extra={"scope": name}, progress=False)
        return ScopeShard(name, module, OK, doc,
                          duration_s=time.perf_counter() - t0)
    except Exception:  # noqa: BLE001 - isolation requirement
        return ScopeShard(name, module, ERROR,
                          error=traceback.format_exc(limit=4),
                          duration_s=time.perf_counter() - t0)


def _run_subprocess(name: str, module: str, opts: OrchestratorOptions
                    ) -> ScopeShard:
    """Run a scope in a standalone interpreter — survives hard crashes."""
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "shard.json")
        cmd = [sys.executable, "-m", "repro.core.orchestrate",
               "--worker", "--module", module, "--out", out,
               "--filter", opts.benchmark_filter,
               "--run-json", json.dumps(asdict(opts.run)),
               "--flags-json", json.dumps(opts.flag_values)]
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=opts.subprocess_timeout)
        except subprocess.TimeoutExpired:
            return ScopeShard(name, module, CRASHED,
                              error=f"timed out after "
                                    f"{opts.subprocess_timeout}s",
                              duration_s=time.perf_counter() - t0)
        if proc.returncode != 0 or not os.path.exists(out):
            payload = None
            if os.path.exists(out):
                try:
                    with open(out) as f:
                        payload = json.load(f)
                except (OSError, json.JSONDecodeError):
                    payload = None
            if isinstance(payload, dict) and "worker_error" in payload:
                # worker survived to report a clean Python exception —
                # an ERROR shard, same as pool/inline would produce
                return ScopeShard(name, module, ERROR,
                                  error=payload["worker_error"],
                                  duration_s=time.perf_counter() - t0)
            return ScopeShard(
                name, module, CRASHED,
                error=f"worker exited {proc.returncode}:\n"
                      f"{proc.stderr[-2000:]}",
                duration_s=time.perf_counter() - t0)
        with open(out) as f:
            doc = json.load(f)
    return ScopeShard(name, module, OK, doc,
                      duration_s=time.perf_counter() - t0)


def _run_pool(items: Sequence[Tuple[str, str]], opts: OrchestratorOptions,
              on_shard) -> List[ScopeShard]:
    """Process-pool execution with subprocess fallback on worker death.

    A worker that raises keeps the pool alive and yields an error shard.
    A worker that *dies* (segfault/``os._exit``) breaks the whole
    ProcessPoolExecutor — every unfinished scope then falls back to its
    own standalone subprocess, so one hostile scope cannot take down the
    rest of the run.
    """
    ctx = multiprocessing.get_context("spawn")
    shards: Dict[str, ScopeShard] = {}
    retry: List[Tuple[str, str]] = []
    run_dict = asdict(opts.run)
    t_submit = time.perf_counter()
    pool = ProcessPoolExecutor(max_workers=max(1, opts.jobs),
                               mp_context=ctx)
    try:
        futs = {pool.submit(_pool_worker, module, run_dict,
                            opts.benchmark_filter,
                            opts.flag_values): (name, module)
                for name, module in items}
        for fut in as_completed(futs):
            name, module = futs[fut]
            try:
                doc, dt = fut.result()
                shards[name] = ScopeShard(name, module, OK, doc,
                                          duration_s=dt)
                on_shard(shards[name])
            except BrokenProcessPool:
                retry.append((name, module))
            except Exception:  # noqa: BLE001 - worker raised, pool alive
                shards[name] = ScopeShard(
                    name, module, ERROR,
                    error=traceback.format_exc(limit=4),
                    duration_s=time.perf_counter() - t_submit)
                on_shard(shards[name])
    finally:
        pool.shutdown(wait=False, cancel_futures=True)

    if retry:
        log.warning("process pool broke; retrying %d scope(s) in "
                    "standalone subprocesses: %s",
                    len(retry), [n for n, _ in retry])
        with ThreadPoolExecutor(max_workers=max(1, opts.jobs)) as tp:
            sub_futs = {tp.submit(_run_subprocess, n, m, opts): n
                        for n, m in retry}
            for fut in as_completed(sub_futs):
                shard = fut.result()
                shards[shard.scope] = shard
                on_shard(shard)
    # preserve the submitted scope order in the output
    return [shards[name] for name, _ in items if name in shards]


# ---------------------------------------------------------------------------
# merge + persistence
# ---------------------------------------------------------------------------

def scope_error_record(shard: ScopeShard) -> Dict[str, Any]:
    """A schema-conforming GB record marking a failed/crashed scope."""
    return {
        "name": f"{shard.scope}/SCOPE_FAILED",
        "run_name": f"{shard.scope}/SCOPE_FAILED",
        "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
        "time_unit": "us",
        "error_occurred": True,
        "error_message": f"[{shard.status}] {shard.error}".strip(),
    }


def merge_shards(shards: Sequence[ScopeShard],
                 context_extra: Optional[Dict[str, Any]] = None,
                 run_id: Optional[str] = None) -> Dict[str, Any]:
    """Concatenate shard documents into one GB-JSON document.

    Top-level schema is identical to the sequential
    :func:`~repro.core.runner.run_benchmarks` output (``context`` +
    ``benchmarks``); shard provenance lives in ``context["shards"]``.
    """
    ctx = build_context(context_extra)
    if run_id:
        ctx["run_id"] = run_id
    ctx["shards"] = [s.meta() for s in shards]
    benchmarks: List[Dict[str, Any]] = []
    for s in shards:
        if s.status == OK and s.doc is not None:
            benchmarks.extend(s.doc.get("benchmarks", []))
        else:
            benchmarks.append(scope_error_record(s))
    return {"context": ctx, "benchmarks": benchmarks}


def default_run_id() -> str:
    return time.strftime("%Y%m%dT%H%M%S") + f"-{os.getpid()}"


def _persist_shard(out_dir: str, shard: ScopeShard) -> None:
    doc = shard.doc if shard.status == OK and shard.doc is not None else {
        "context": {"scope": shard.scope, **shard.meta()},
        "benchmarks": [scope_error_record(shard)],
    }
    write_json(doc, os.path.join(out_dir, f"{shard.scope}.json"))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def execute(mgr, registry, opts: OrchestratorOptions,
            context_extra: Optional[Dict[str, Any]] = None) -> RunResult:
    """Run every enabled scope of ``mgr`` under ``opts``; merge the shards.

    ``mgr`` must already be loaded/configured; for inline mode it must
    also be registered (``mgr.register_all()``).  External scopes (added
    with ``add_scope``, no importable module) always run inline — a
    worker cannot re-import them.
    """
    items = mgr.dispatchable()
    run_id = opts.run_id or default_run_id()
    out_dir = None
    if opts.results_dir:
        out_dir = os.path.join(opts.results_dir, run_id)
        os.makedirs(out_dir, exist_ok=True)

    def on_shard(shard: ScopeShard) -> None:
        log.info("scope %s: %s (%d records, %.2fs)", shard.scope,
                 shard.status,
                 len(shard.doc["benchmarks"]) if shard.doc else 0,
                 shard.duration_s)
        if out_dir:
            _persist_shard(out_dir, shard)

    mode = opts.mode()
    parallel_items = [(n, m) for n, m in items if m != "<external>"]
    inline_items = [(n, m) for n, m in items if m == "<external>"]
    if mode == "inline":
        inline_items, parallel_items = items, []

    shards: List[ScopeShard] = []
    for name, module in inline_items:
        shard = _run_inline(name, module, registry, opts)
        on_shard(shard)
        shards.append(shard)
    if parallel_items:
        if mode == "subprocess":
            with ThreadPoolExecutor(max_workers=max(1, opts.jobs)) as tp:
                futs = {tp.submit(_run_subprocess, n, m, opts): (n, m)
                        for n, m in parallel_items}
                got = {}
                for fut in as_completed(futs):
                    shard = fut.result()
                    on_shard(shard)
                    got[shard.scope] = shard
            shards.extend(got[n] for n, _ in parallel_items if n in got)
        else:
            shards.extend(_run_pool(parallel_items, opts, on_shard))

    doc = merge_shards(shards, context_extra=context_extra, run_id=run_id)
    if out_dir:
        write_json(doc, os.path.join(out_dir, "merged.json"))
        log.info("wrote %s (%d records from %d shards)",
                 os.path.join(out_dir, "merged.json"),
                 len(doc["benchmarks"]), len(shards))
    return RunResult(doc=doc, shards=shards, run_id=run_id, out_dir=out_dir)


# ---------------------------------------------------------------------------
# standalone worker CLI (the subprocess-isolation entry)
# ---------------------------------------------------------------------------

def _worker_main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.core.orchestrate")
    ap.add_argument("--worker", action="store_true", required=True)
    ap.add_argument("--module", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--filter", default=".*")
    ap.add_argument("--run-json", default="{}")
    ap.add_argument("--flags-json", default="{}")
    ns = ap.parse_args(argv)
    try:
        doc = run_one_scope(ns.module,
                            RunOptions(**json.loads(ns.run_json)),
                            ns.filter, json.loads(ns.flags_json))
    except Exception:  # noqa: BLE001 - report, don't look like a crash
        # a clean Python failure is an ERROR shard, not a CRASHED one —
        # write the traceback so the parent can tell them apart
        write_json({"worker_error": traceback.format_exc(limit=6)}, ns.out)
        return 3
    write_json(doc, ns.out)
    return 0


if __name__ == "__main__":
    sys.exit(_worker_main())
