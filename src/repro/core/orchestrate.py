"""Run-orchestration subsystem — plan → schedule → shard → merge.

This is the run stage of the SCOPE binary (paper Fig. 2(d)) rebuilt as an
orchestrator instead of a sequential loop.  Execution is planned at one of
two granularities (``--shard-grain``):

  * **benchmark** (default when ``--jobs > 1``) — the work-plan layer
    (:mod:`repro.core.plan`) enumerates the registry into addressable
    benchmark *instances*; items are binned across workers with greedy
    longest-processing-time using cost hints from a prior run, each
    completed instance is streamed to ``results/<run-id>/shards/<id>.json``,
    and ``manifest.json`` records plan → shard status.  An interrupted run
    resumes with ``--resume <run-id>`` (completed instances are skipped,
    exaCB-style), and a crashed instance degrades only itself — the rest
    of its scope still reports;
  * **scope** (the paper's granularity, default when ``--jobs 1``) — each
    enabled scope is one schedulable unit yielding one shard under
    ``results/<run-id>/<scope>.json``.

Shared machinery at both grains:

  * **parallelism** — work runs in fresh interpreters (``--jobs N``), each
    with its own registry/flags, so parallel work cannot contend on the
    global registry or JAX state;
  * **measurement** — the full :class:`~repro.core.runner.RunOptions`
    (including the ``--meters`` meter-stack selection,
    :mod:`repro.core.measure`) travels to every worker as JSON at both
    grains, so a subprocess worker measures exactly what an inline run
    would: device-fenced wall time, real CPU time, and any opt-in
    cost-model counters land in its shard records unchanged;
  * **failure isolation** — a unit that *errors* produces an error shard;
    a unit that *kills its interpreter* (segfault, ``os._exit``) is
    retried in a standalone subprocess (scope grain) or narrowed down to
    the single poisonous instance (benchmark grain) and degraded to an
    error record;
  * **merged document** — shards are merged in plan order into one
    schema-identical GB-JSON document (``merged.json``), so ``--jobs``,
    ``--shard-grain``, and ``--resume`` never change the merged output's
    benchmark names, order, or schema.  Provenance lives inside
    ``context["shards"]`` (and ``context["instances"]`` at benchmark
    grain); any Google-Benchmark-compatible consumer (ScopePlot included)
    reads merged output unchanged;
  * **baseline diffing** — the merged document is what
    :mod:`repro.core.baseline` stores and compares (``python -m repro
    compare A.json B.json``);
  * **run history** — a persisted run appends one record per benchmark
    instance to ``<results-dir>/history.jsonl`` at merge time
    (:mod:`repro.core.history`), the store ``python -m repro report``
    renders trends from and ``--baseline results/history.jsonl`` gates
    against.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import shutil
import subprocess
import sys
import tempfile
import time
import traceback
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor, as_completed
from concurrent.futures.process import BrokenProcessPool
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .history import append_run
from .logging import get_logger
from .plan import Plan, PlanItem, build_plan, load_cost_hints, scope_worklist
from .runner import (RunOptions, run_benchmarks, run_single_instance,
                     write_json)
from .sysinfo import build_context

log = get_logger("orchestrate")

# Shard status values.
OK = "ok"            # unit ran; doc holds its records (may include errors)
ERROR = "error"      # unit failed to import/register/run; no usable records
CRASHED = "crashed"  # unit killed its interpreter(s); no records
PENDING = "pending"  # planned but not yet executed (manifest only)
PARTIAL = "partial"  # scope rollup: some instances ok, some not

EXTERNAL = "<external>"   # module marker for add_scope()-registered scopes


def _spawn_safe_main() -> bool:
    main = sys.modules.get("__main__")
    if getattr(main, "__spec__", None) is not None:   # python -m …
        return True
    path = getattr(main, "__file__", None)
    return bool(path and os.path.exists(path))


@dataclass
class OrchestratorOptions:
    """How to schedule the enabled scopes' benchmarks."""

    jobs: int = 1                   # worker parallelism (1 → inline)
    isolate: str = "auto"           # auto | inline | pool | subprocess
    shard_grain: str = "auto"       # auto | benchmark | scope
    benchmark_filter: str = ".*"
    run: RunOptions = field(default_factory=RunOptions)
    # parsed flag values forwarded to workers (scopes read global FLAGS)
    flag_values: Dict[str, Any] = field(default_factory=dict)
    results_dir: Optional[str] = None   # persist shards+merged when set
    run_id: Optional[str] = None        # defaults to a timestamp
    resume: bool = False                # re-open results_dir/run_id; skip
    #                                     instances whose shard is complete
    cost_source: Optional[str] = None   # prior run dir / GB doc → cost hints
    subprocess_timeout: float = 1800.0
    # delta runs (--since / repro ci): instance_id → latest history
    # record vouching for a fingerprint-fresh instance; those instances
    # are materialized as cached results instead of executed
    cached_results: Optional[Dict[str, Dict[str, Any]]] = None
    history_tag: Optional[str] = None   # tag for appended history records

    def grain(self) -> str:
        if self.shard_grain != "auto":
            return self.shard_grain
        # resuming/delta-skipping only makes sense at instance grain
        return "benchmark" if self.jobs > 1 or self.resume \
            or self.cached_results is not None else "scope"

    def mode(self) -> str:
        if self.isolate != "auto":
            return self.isolate
        if self.jobs <= 1:
            return "inline"
        # spawn re-executes __main__; a parent without a real main module
        # (stdin, embedded interpreter) would break every pool worker at
        # startup, so fall straight to standalone subprocesses there.
        return "pool" if _spawn_safe_main() else "subprocess"


@dataclass
class ScopeShard:
    """One scope's contribution to a run."""

    scope: str
    module: str
    status: str = OK
    doc: Optional[Dict[str, Any]] = None   # GB-JSON document when status==OK
    error: str = ""
    duration_s: float = 0.0

    def meta(self) -> Dict[str, Any]:
        m: Dict[str, Any] = {"scope": self.scope, "module": self.module,
                             "status": self.status,
                             "duration_s": round(self.duration_s, 6)}
        if self.error:
            m["error"] = self.error
        return m


@dataclass
class InstanceResult:
    """One benchmark instance's contribution to a plan-grained run."""

    item: PlanItem
    status: str = PENDING
    doc: Optional[Dict[str, Any]] = None   # GB-JSON doc for this instance
    error: str = ""
    duration_s: float = 0.0
    started: Optional[float] = None        # epoch seconds (manifest proof
    finished: Optional[float] = None       #  that --resume didn't re-run)
    cached: bool = False                   # satisfied from a previous run

    def meta(self) -> Dict[str, Any]:
        m = {**self.item.meta(), "status": self.status,
             "shard": f"shards/{self.item.instance_id}.json",
             "duration_s": round(self.duration_s, 6),
             "started": self.started, "finished": self.finished}
        if self.error:
            m["error"] = self.error[-2000:]
        if self.cached:
            m["cached"] = True
        return m


@dataclass
class RunResult:
    """Merged document + per-scope shards, as returned by :func:`execute`.

    Plan-grained runs additionally expose the plan and the per-instance
    results (``instances``); per-scope shards are then rollups so
    scope-grained consumers keep working unchanged.
    """

    doc: Dict[str, Any]
    shards: List[ScopeShard]
    run_id: str
    out_dir: Optional[str] = None
    plan: Optional[Plan] = None
    instances: List[InstanceResult] = field(default_factory=list)

    def shard(self, scope: str) -> Optional[ScopeShard]:
        for s in self.shards:
            if s.scope == scope:
                return s
        return None

    def instance(self, name: str) -> Optional[InstanceResult]:
        for r in self.instances:
            if r.item.name == name or r.item.instance_id == name:
                return r
        return None


# ---------------------------------------------------------------------------
# scope-grain worker (runs in a fresh interpreter under pool/subprocess)
# ---------------------------------------------------------------------------

def run_one_scope(module: str, run_opts: RunOptions, benchmark_filter: str,
                  flag_values: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    """Load ONE scope module and run its benchmarks; return the GB-JSON doc.

    Top-level (picklable) so it can be dispatched to a spawn-context
    process pool.  Uses the process-global registry/flags/hooks because
    scope bodies read them (e.g. ``FLAGS.get("example/greet")``) — under
    pool/subprocess isolation the process is fresh, so this *is* a clean
    slate; callers running inline should prefer :func:`execute`.
    """
    from .flags import FLAGS
    from .hooks import HOOKS
    from .registry import REGISTRY
    from .scope import ScopeManager

    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load([module])
    loaded = mgr.scopes()[0]
    if not loaded.available:
        raise RuntimeError(f"scope module {module} failed to import:\n"
                           f"{loaded.error}")
    for name, value in (flag_values or {}).items():
        FLAGS.set(name, value)
    rc = HOOKS.run_pre_parse()
    if rc is None:
        rc = HOOKS.run_post_parse()
    if rc is not None:
        raise RuntimeError(f"scope {loaded.scope.name} init hook requested "
                           f"exit ({rc})")
    mgr.register_all()
    if not loaded.available:
        raise RuntimeError(f"scope {loaded.scope.name} registration "
                           f"failed:\n{loaded.error}")
    benches = REGISTRY.filter(benchmark_filter,
                              scopes=[loaded.scope.name])
    return run_benchmarks(benches, run_opts,
                          context_extra={"scope": loaded.scope.name},
                          progress=False)


def _pool_worker(module: str, run_opts_dict: Dict[str, Any],
                 benchmark_filter: str, flag_values: Dict[str, Any]
                 ) -> Tuple[Dict[str, Any], float]:
    """Returns (doc, runtime) — timed in the worker, excluding queue wait."""
    t0 = time.perf_counter()
    doc = run_one_scope(module, RunOptions(**run_opts_dict),
                        benchmark_filter, flag_values)
    return doc, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# scope-grain execution strategies
# ---------------------------------------------------------------------------

def _run_inline(name: str, module: str, registry, opts: OrchestratorOptions
                ) -> ScopeShard:
    """Run a scope in-process against the parent's already-built registry."""
    t0 = time.perf_counter()
    try:
        benches = registry.filter(opts.benchmark_filter, scopes=[name])
        doc = run_benchmarks(benches, opts.run,
                             context_extra={"scope": name}, progress=False)
        return ScopeShard(name, module, OK, doc,
                          duration_s=time.perf_counter() - t0)
    except Exception:  # noqa: BLE001 - isolation requirement
        return ScopeShard(name, module, ERROR,
                          error=traceback.format_exc(limit=4),
                          duration_s=time.perf_counter() - t0)


def _run_subprocess(name: str, module: str, opts: OrchestratorOptions
                    ) -> ScopeShard:
    """Run a scope in a standalone interpreter — survives hard crashes."""
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "shard.json")
        cmd = [sys.executable, "-m", "repro.core.orchestrate",
               "--worker", "--module", module, "--out", out,
               "--filter", opts.benchmark_filter,
               "--run-json", json.dumps(asdict(opts.run)),
               "--flags-json", json.dumps(opts.flag_values)]
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=opts.subprocess_timeout)
        except subprocess.TimeoutExpired:
            return ScopeShard(name, module, CRASHED,
                              error=f"timed out after "
                                    f"{opts.subprocess_timeout}s",
                              duration_s=time.perf_counter() - t0)
        if proc.returncode != 0 or not os.path.exists(out):
            payload = None
            if os.path.exists(out):
                try:
                    with open(out) as f:
                        payload = json.load(f)
                except (OSError, json.JSONDecodeError):
                    payload = None
            if isinstance(payload, dict) and "worker_error" in payload:
                # worker survived to report a clean Python exception —
                # an ERROR shard, same as pool/inline would produce
                return ScopeShard(name, module, ERROR,
                                  error=payload["worker_error"],
                                  duration_s=time.perf_counter() - t0)
            return ScopeShard(
                name, module, CRASHED,
                error=f"worker exited {proc.returncode}:\n"
                      f"{proc.stderr[-2000:]}",
                duration_s=time.perf_counter() - t0)
        with open(out) as f:
            doc = json.load(f)
    return ScopeShard(name, module, OK, doc,
                      duration_s=time.perf_counter() - t0)


def _run_pool(items: Sequence[Tuple[str, str]], opts: OrchestratorOptions,
              on_shard) -> List[ScopeShard]:
    """Process-pool execution with subprocess fallback on worker death.

    A worker that raises keeps the pool alive and yields an error shard.
    A worker that *dies* (segfault/``os._exit``) breaks the whole
    ProcessPoolExecutor — every unfinished scope then falls back to its
    own standalone subprocess, so one hostile scope cannot take down the
    rest of the run.
    """
    ctx = multiprocessing.get_context("spawn")
    shards: Dict[str, ScopeShard] = {}
    retry: List[Tuple[str, str]] = []
    run_dict = asdict(opts.run)
    t_submit = time.perf_counter()
    pool = ProcessPoolExecutor(max_workers=max(1, opts.jobs),
                               mp_context=ctx)
    try:
        futs = {pool.submit(_pool_worker, module, run_dict,
                            opts.benchmark_filter,
                            opts.flag_values): (name, module)
                for name, module in items}
        for fut in as_completed(futs):
            name, module = futs[fut]
            try:
                doc, dt = fut.result()
                shards[name] = ScopeShard(name, module, OK, doc,
                                          duration_s=dt)
                on_shard(shards[name])
            except BrokenProcessPool:
                retry.append((name, module))
            except Exception:  # noqa: BLE001 - worker raised, pool alive
                shards[name] = ScopeShard(
                    name, module, ERROR,
                    error=traceback.format_exc(limit=4),
                    duration_s=time.perf_counter() - t_submit)
                on_shard(shards[name])
    finally:
        pool.shutdown(wait=False, cancel_futures=True)

    if retry:
        log.warning("process pool broke; retrying %d scope(s) in "
                    "standalone subprocesses: %s",
                    len(retry), [n for n, _ in retry])
        with ThreadPoolExecutor(max_workers=max(1, opts.jobs)) as tp:
            sub_futs = {tp.submit(_run_subprocess, n, m, opts): n
                        for n, m in retry}
            for fut in as_completed(sub_futs):
                shard = fut.result()
                shards[shard.scope] = shard
                on_shard(shard)
    # preserve the submitted scope order in the output
    return [shards[name] for name, _ in items if name in shards]


# ---------------------------------------------------------------------------
# merge + persistence (shared)
# ---------------------------------------------------------------------------

def _gb_error_record(name: str, status: str, error: str) -> Dict[str, Any]:
    return {
        "name": name,
        "run_name": name,
        "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
        "time_unit": "us",
        "error_occurred": True,
        "error_message": f"[{status}] {error}".strip(),
    }


def scope_error_record(shard: ScopeShard) -> Dict[str, Any]:
    """A schema-conforming GB record marking a failed/crashed scope."""
    return _gb_error_record(f"{shard.scope}/SCOPE_FAILED", shard.status,
                            shard.error)


def cached_instance_result(item: PlanItem, rec: Dict[str, Any]
                           ) -> InstanceResult:
    """Materialize a delta-skipped instance from its history record.

    The merged document must stay *complete* on a sparse delta run, so
    the skipped instance contributes a schema-conforming GB record
    replaying its latest measured mean — marked ``cached: true`` (plus
    the run it echoes) so history appending, drift pooling and readers
    can tell a replay from a measurement.
    """
    gb: Dict[str, Any] = {
        "name": item.name, "run_name": item.name, "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": max(1, int(rec.get("n") or 1)),
        "real_time": float(rec.get("mean_s") or 0.0),
        "cpu_time": float(rec.get("mean_s") or 0.0),
        "time_unit": "s",
        "cached": True,
        "cached_from_run": rec.get("run_id", ""),
    }
    counters = rec.get("counters")
    if isinstance(counters, dict):
        for key, value in counters.items():
            if isinstance(value, (int, float)) \
                    and not isinstance(value, bool):
                gb.setdefault(key, value)
    doc = {"context": {}, "benchmarks": [gb]}
    now = time.time()
    return InstanceResult(item, OK, doc, duration_s=0.0,
                          started=now, finished=now, cached=True)


def instance_error_record(name: str, status: str, error: str
                          ) -> Dict[str, Any]:
    """A schema-conforming GB record for one failed/crashed instance.

    Unlike a scope failure, the record keeps the *instance's own name* —
    siblings in the same scope report normally, and baseline comparison
    attributes the failure to exactly the benchmark that died.
    """
    return _gb_error_record(name, status, error)


def merge_shards(shards: Sequence[ScopeShard],
                 context_extra: Optional[Dict[str, Any]] = None,
                 run_id: Optional[str] = None) -> Dict[str, Any]:
    """Concatenate scope shard documents into one GB-JSON document.

    Top-level schema is identical to the sequential
    :func:`~repro.core.runner.run_benchmarks` output (``context`` +
    ``benchmarks``); shard provenance lives in ``context["shards"]``.
    """
    ctx = build_context(context_extra)
    if run_id:
        ctx["run_id"] = run_id
    ctx["shards"] = [s.meta() for s in shards]
    benchmarks: List[Dict[str, Any]] = []
    for s in shards:
        if s.status == OK and s.doc is not None:
            benchmarks.extend(s.doc.get("benchmarks", []))
        else:
            benchmarks.append(scope_error_record(s))
    return {"context": ctx, "benchmarks": benchmarks}


def default_run_id() -> str:
    return time.strftime("%Y%m%dT%H%M%S") + f"-{os.getpid()}"


def _atomic_write_json(doc: Dict[str, Any], path: str) -> None:
    """Write-then-rename so crash-time readers never see a torn file."""
    tmp = path + ".tmp"
    write_json(doc, tmp)
    os.replace(tmp, path)


def _append_history(results_dir: str, doc: Dict[str, Any],
                    run_id: str, tag: Optional[str] = None) -> None:
    """Best-effort run-history append — never fails a finished run."""
    try:
        append_run(results_dir, doc, run_id=run_id, tag=tag)
    except Exception:  # noqa: BLE001 - history is an artifact, not a gate
        log.warning("run-history append failed for %s:\n%s", run_id,
                    traceback.format_exc(limit=2))


def _persist_shard(out_dir: str, shard: ScopeShard) -> None:
    doc = shard.doc if shard.status == OK and shard.doc is not None else {
        "context": {"scope": shard.scope, **shard.meta()},
        "benchmarks": [scope_error_record(shard)],
    }
    write_json(doc, os.path.join(out_dir, f"{shard.scope}.json"))


# ---------------------------------------------------------------------------
# plan-grain: manifest + instance shards
# ---------------------------------------------------------------------------

def manifest_path(out_dir: str) -> str:
    return os.path.join(out_dir, "manifest.json")


def read_manifest(out_dir: str) -> Dict[str, Any]:
    with open(manifest_path(out_dir)) as f:
        return json.load(f)


def write_manifest(out_dir: str, run_id: str, plan: Plan,
                   results: Dict[str, InstanceResult]) -> None:
    """Record plan → shard status, rewritten as instances complete."""
    items = []
    for item in plan.items:
        r = results.get(item.instance_id)
        if r is not None:
            items.append(r.meta())
        else:
            items.append({**item.meta(), "status": PENDING,
                          "shard": f"shards/{item.instance_id}.json"})
    _atomic_write_json({
        "run_id": run_id,
        "grain": "benchmark",
        "total": len(plan.items),
        "completed": sum(1 for r in results.values() if r.status == OK),
        "items": items,
    }, manifest_path(out_dir))


def _instance_shard_file(spool: str, item: PlanItem) -> str:
    return os.path.join(spool, f"{item.instance_id}.json")


def _write_instance_shard(spool: str, res: InstanceResult) -> None:
    doc = res.doc if res.doc is not None else {
        "context": {},
        "benchmarks": [instance_error_record(res.item.name, res.status,
                                             res.error)],
    }
    doc.setdefault("context", {})["instance"] = res.meta()
    _atomic_write_json(doc, _instance_shard_file(spool, res.item))


def _load_instance_shard(spool: str, item: PlanItem
                         ) -> Optional[InstanceResult]:
    """Read one instance's spool shard; None if absent or torn."""
    path = _instance_shard_file(spool, item)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    meta = doc.get("context", {}).get("instance", {})
    return InstanceResult(
        item=item, status=meta.get("status", OK), doc=doc,
        error=meta.get("error", ""),
        duration_s=meta.get("duration_s", 0.0),
        started=meta.get("started"), finished=meta.get("finished"))


# ---------------------------------------------------------------------------
# plan-grain: execution
# ---------------------------------------------------------------------------

def _instance_status(doc: Dict[str, Any]) -> Tuple[str, str]:
    """(status, error) from a freshly-run instance document.

    An instance whose every record errored is an ERROR result — it will
    be re-attempted by ``--resume`` — while partial/record-level errors
    (e.g. one repetition skipped) leave the instance OK, matching
    scope-grain semantics.
    """
    recs = doc.get("benchmarks", [])
    if recs and all(r.get("error_occurred") for r in recs):
        return ERROR, str(recs[0].get("error_message") or "")
    return OK, ""


def _run_instance_inline(item: PlanItem, registry,
                         opts: OrchestratorOptions) -> InstanceResult:
    """Run one plan item in-process against the parent's registry."""
    started = time.time()
    t0 = time.perf_counter()
    try:
        bench = registry.get(item.family)
        doc = run_single_instance([bench], item.name, opts.run)
        status, error = _instance_status(doc)
    except Exception:  # noqa: BLE001 - isolation requirement
        status, error = ERROR, traceback.format_exc(limit=4)
        doc = {"context": {},
               "benchmarks": [instance_error_record(item.name, status,
                                                    error)]}
    return InstanceResult(item, status, doc, error,
                          duration_s=time.perf_counter() - t0,
                          started=started, finished=time.time())


def run_plan_items(items_meta: Sequence[Dict[str, Any]],
                   run_opts: RunOptions,
                   flag_values: Optional[Dict[str, Any]],
                   spool: str) -> int:
    """Worker body: run a bin of plan items, streaming instance shards.

    Loads every scope module the bin references once (imports are the
    expensive part — JAX — so instances are batched per worker, not
    spawned one interpreter each), then executes the items in plan order,
    writing ``<spool>/<instance_id>.json`` after each.  A Python-level
    failure degrades that instance to an error shard and the worker keeps
    going; only interpreter death stops the stream — the parent then
    narrows the gap down via solo retries.
    """
    from .flags import FLAGS
    from .hooks import HOOKS
    from .registry import REGISTRY
    from .scope import ScopeManager

    REGISTRY.reset()
    mgr = ScopeManager()
    modules: List[str] = []
    for m in items_meta:
        if m["module"] not in modules:
            modules.append(m["module"])
    mgr.load(modules)
    for name, value in (flag_values or {}).items():
        FLAGS.set(name, value)
    rc = HOOKS.run_pre_parse()
    if rc is None:
        rc = HOOKS.run_post_parse()
    init_error = f"init hook requested exit ({rc})" if rc is not None else ""
    if not init_error:
        mgr.register_all()
    unavailable = {s.scope.name: s.error for s in mgr.scopes()
                   if not s.available}

    for m in items_meta:
        item = PlanItem.from_meta(m)
        started = time.time()
        t0 = time.perf_counter()
        try:
            if init_error:
                raise RuntimeError(init_error)
            if item.scope in unavailable:
                raise RuntimeError(f"scope {item.scope} unavailable in "
                                   f"worker:\n{unavailable[item.scope]}")
            bench = REGISTRY.get(item.family)
            doc = run_single_instance([bench], item.name, run_opts)
            status, error = _instance_status(doc)
        except Exception:  # noqa: BLE001 - isolate instance failures
            status, error = ERROR, traceback.format_exc(limit=4)
            doc = {"context": {},
                   "benchmarks": [instance_error_record(item.name, status,
                                                        error)]}
        res = InstanceResult(item, status, doc, error,
                             duration_s=time.perf_counter() - t0,
                             started=started, finished=time.time())
        _write_instance_shard(spool, res)
    return 0


def _spawn_plan_worker(items: Sequence[PlanItem], spool: str,
                       opts: OrchestratorOptions) -> Tuple[int, str]:
    """Run a bin of items in a standalone interpreter; (returncode, stderr).

    Results travel through the spool directory, not the return value, so
    a worker that dies mid-bin still leaves every finished instance's
    shard behind.
    """
    fd, items_file = tempfile.mkstemp(suffix=".items", dir=spool)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump([i.meta() for i in items], f)
        cmd = [sys.executable, "-m", "repro.core.orchestrate",
               "--worker-plan", "--items-json", items_file,
               "--spool", spool,
               "--run-json", json.dumps(asdict(opts.run)),
               "--flags-json", json.dumps(opts.flag_values)]
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=opts.subprocess_timeout)
        except subprocess.TimeoutExpired:
            return -9, f"timed out after {opts.subprocess_timeout}s"
        return proc.returncode, proc.stderr or ""
    finally:
        try:
            os.unlink(items_file)
        except OSError:
            pass


def _run_bin(bin_items: Sequence[PlanItem], spool: str,
             opts: OrchestratorOptions) -> Dict[str, InstanceResult]:
    """Execute one worker bin; recover per-instance from worker death.

    If the batch interpreter dies, finished instances are recovered from
    the spool and each missing one is retried in its own interpreter —
    the instance that kills its solo worker too is marked CRASHED, its
    bin-mates all still report.
    """
    rc, stderr = _spawn_plan_worker(bin_items, spool, opts)
    out: Dict[str, InstanceResult] = {}
    missing: List[PlanItem] = []
    for item in bin_items:
        res = _load_instance_shard(spool, item)
        if res is not None:
            out[item.instance_id] = res
        else:
            missing.append(item)
    if missing and len(bin_items) > 1:
        log.warning("plan worker died (exit %s); retrying %d instance(s) "
                    "solo: %s", rc, len(missing),
                    [i.name for i in missing])
    for item in missing:
        if len(bin_items) > 1:
            rc, stderr = _spawn_plan_worker([item], spool, opts)
            res = _load_instance_shard(spool, item)
            if res is not None:
                out[item.instance_id] = res
                continue
        now = time.time()
        res = InstanceResult(
            item, CRASHED, None,
            error=f"worker exited {rc}:\n{stderr[-2000:]}",
            started=now, finished=now)
        _write_instance_shard(spool, res)
        # re-read so doc/meta match what a resume would reconstruct
        out[item.instance_id] = _load_instance_shard(spool, item) or res
    return out


def merge_plan(plan: Plan, results: Dict[str, InstanceResult],
               context_extra: Optional[Dict[str, Any]] = None,
               run_id: Optional[str] = None,
               rollups: Optional[List[ScopeShard]] = None
               ) -> Dict[str, Any]:
    """Merge instance results into one GB-JSON document, in *plan order*.

    Plan order — not completion order — is what makes the merged document
    deterministic across ``--jobs`` and bin assignments: it is identical,
    benchmark for benchmark, to an inline scope-grained run.  The plan
    enumerates scope by scope, so concatenating the per-scope rollups
    (pass precomputed ``rollups`` to avoid rebuilding them) *is* plan
    order.
    """
    rollups = _scope_rollups(plan, results) if rollups is None else rollups
    ctx = build_context(context_extra)
    if run_id:
        ctx["run_id"] = run_id
    ctx["shard_grain"] = "benchmark"
    ctx["shards"] = [r.meta() for r in rollups]
    ctx["instances"] = [
        results[i.instance_id].meta() if i.instance_id in results
        else {**i.meta(), "status": PENDING}
        for i in plan.items
    ]
    benchmarks: List[Dict[str, Any]] = []
    for shard in rollups:
        benchmarks.extend(shard.doc.get("benchmarks", []))
    return {"context": ctx, "benchmarks": benchmarks}


def _scope_rollups(plan: Plan, results: Dict[str, InstanceResult]
                   ) -> List[ScopeShard]:
    """Per-scope ScopeShard views over instance results.

    Keeps scope-grained consumers (benchmarks/run.py, ScopePlot's
    ``shards()``) working on plan-grained runs: ``ok`` when every
    instance succeeded, ``partial`` when some did, ``error``/``crashed``
    when none did.
    """
    shards: List[ScopeShard] = []
    for scope in plan.scopes():
        scope_items = [i for i in plan.items if i.scope == scope]
        rs = [results.get(i.instance_id) for i in scope_items]
        statuses = [r.status if r is not None else PENDING for r in rs]
        n_ok = sum(1 for s in statuses if s == OK)
        if n_ok == len(statuses):
            status = OK
        elif n_ok:
            status = PARTIAL
        elif CRASHED in statuses:
            status = CRASHED
        else:
            status = ERROR
        benchmarks: List[Dict[str, Any]] = []
        for item, r in zip(scope_items, rs):
            if r is not None and r.doc is not None:
                benchmarks.extend(r.doc.get("benchmarks", []))
            else:
                benchmarks.append(instance_error_record(
                    item.name, r.status if r else PENDING,
                    r.error if r else "never executed"))
        error = "; ".join(
            f"{i.name}: {r.error.strip().splitlines()[-1]}"
            for i, r in zip(scope_items, rs)
            if r is not None and r.status != OK and r.error)[:2000]
        shards.append(ScopeShard(
            scope, scope_items[0].module, status,
            {"context": {"scope": scope}, "benchmarks": benchmarks},
            error=error,
            duration_s=sum(r.duration_s for r in rs if r is not None)))
    return shards


def _execute_plan_grain(mgr, registry, opts: OrchestratorOptions,
                        context_extra: Optional[Dict[str, Any]] = None
                        ) -> RunResult:
    """Benchmark-grained execution: plan → LPT bins → shards → merge."""
    cost_hints: Dict[str, float] = {}
    if opts.cost_source:
        try:
            cost_hints = load_cost_hints(opts.cost_source)
        except (OSError, json.JSONDecodeError) as e:
            log.warning("cost source %s unreadable (%s); planning without "
                        "hints", opts.cost_source, e)
    plan = build_plan(mgr, registry, opts.benchmark_filter,
                      cost_hints=cost_hints,
                      param_filter=opts.run.param_filter)
    run_id = opts.run_id or default_run_id()
    out_dir = None
    if opts.results_dir:
        out_dir = os.path.join(opts.results_dir, run_id)
    if opts.resume and (out_dir is None or not os.path.isdir(out_dir)):
        raise FileNotFoundError(
            f"--resume {run_id}: no run directory "
            f"{out_dir or '(need --results-dir)'}")

    spool_tmp = None
    if out_dir:
        spool = os.path.join(out_dir, "shards")
        os.makedirs(spool, exist_ok=True)
    else:
        spool = spool_tmp = tempfile.mkdtemp(prefix="repro-spool-")

    try:
        results: Dict[str, InstanceResult] = {}
        if opts.resume:
            # shard files are the source of truth — an orchestrator killed
            # between a worker's shard write and the next manifest rewrite
            # must not re-run that instance
            for item in plan.items:
                res = _load_instance_shard(spool, item)
                if res is not None and res.status == OK:
                    res.cached = True
                    results[item.instance_id] = res
            log.info("resume %s: %d/%d instance(s) already complete",
                     run_id, len(results), len(plan.items))
        if opts.cached_results:
            # delta run: fingerprint-fresh instances replay their latest
            # history record instead of executing (repro.core.fingerprint)
            skipped = 0
            for item in plan.items:
                rec = opts.cached_results.get(item.instance_id)
                if rec is None or item.instance_id in results:
                    continue
                res = cached_instance_result(item, rec)
                if out_dir:
                    _write_instance_shard(spool, res)
                results[item.instance_id] = res
                skipped += 1
            log.info("delta %s: %d/%d instance(s) fresh (cached), "
                     "%d to run", run_id, skipped, len(plan.items),
                     len(plan.items) - len(results))
        pending = [i for i in plan.items if i.instance_id not in results]

        if out_dir:
            write_manifest(out_dir, run_id, plan, results)

        def on_result(res: InstanceResult) -> None:
            results[res.item.instance_id] = res
            log.info("instance %s: %s (%.2fs)", res.item.name, res.status,
                     res.duration_s)
            if out_dir:
                write_manifest(out_dir, run_id, plan, results)

        mode = opts.mode()
        # external scopes (add_scope, no importable module) can't be
        # re-imported by a worker — they always run inline in the parent
        inline_items = [i for i in pending
                        if mode == "inline" or i.module == EXTERNAL]
        worker_items = [i for i in pending if i not in inline_items]

        if worker_items:
            bins = plan.bins(opts.jobs, worker_items)
            log.info("scheduling %d instance(s) across %d worker bin(s) "
                     "(LPT, predicted makespan %.2fs)",
                     len(worker_items), len(bins),
                     max(sum(plan.cost_of(i) for i in b) for b in bins))
            with ThreadPoolExecutor(max_workers=max(1, opts.jobs)) as tp:
                futs = [tp.submit(_run_bin, b, spool, opts) for b in bins]
                for fut in as_completed(futs):
                    for res in fut.result().values():
                        on_result(res)
        for item in inline_items:
            res = _run_instance_inline(item, registry, opts)
            if out_dir:
                _write_instance_shard(spool, res)
            on_result(res)

        shards = _scope_rollups(plan, results)
        doc = merge_plan(plan, results, context_extra=context_extra,
                         run_id=run_id, rollups=shards)
        if out_dir:
            write_json(doc, os.path.join(out_dir, "merged.json"))
            log.info("wrote %s (%d records from %d instances)",
                     os.path.join(out_dir, "merged.json"),
                     len(doc["benchmarks"]), len(plan.items))
            _append_history(opts.results_dir, doc, run_id,
                            tag=opts.history_tag)
        return RunResult(doc=doc, shards=shards, run_id=run_id,
                         out_dir=out_dir, plan=plan,
                         instances=[results[i.instance_id]
                                    for i in plan.items
                                    if i.instance_id in results])
    finally:
        if spool_tmp:
            shutil.rmtree(spool_tmp, ignore_errors=True)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def execute(mgr, registry, opts: OrchestratorOptions,
            context_extra: Optional[Dict[str, Any]] = None) -> RunResult:
    """Run every enabled scope of ``mgr`` under ``opts``; merge the shards.

    ``mgr`` must already be loaded/configured *and registered*
    (``mgr.register_all()``) — plan construction enumerates the registry.
    ``opts.grain()`` picks the schedulable unit: benchmark instances
    (:func:`_execute_plan_grain`) or whole scopes.  External scopes
    (added with ``add_scope``, no importable module) always run inline —
    a worker cannot re-import them.
    """
    if opts.grain() == "benchmark":
        return _execute_plan_grain(mgr, registry, opts, context_extra)
    if opts.resume:
        # silently re-running everything would invalidate the manifest
        # timestamps resume exists to preserve
        raise ValueError("--resume requires benchmark shard grain "
                         "(drop --shard-grain scope)")
    if opts.cached_results is not None:
        raise ValueError("--since delta runs require benchmark shard "
                         "grain (drop --shard-grain scope)")

    items = scope_worklist(mgr)
    run_id = opts.run_id or default_run_id()
    out_dir = None
    if opts.results_dir:
        out_dir = os.path.join(opts.results_dir, run_id)
        os.makedirs(out_dir, exist_ok=True)

    def on_shard(shard: ScopeShard) -> None:
        log.info("scope %s: %s (%d records, %.2fs)", shard.scope,
                 shard.status,
                 len(shard.doc["benchmarks"]) if shard.doc else 0,
                 shard.duration_s)
        if out_dir:
            _persist_shard(out_dir, shard)

    mode = opts.mode()
    parallel_items = [(n, m) for n, m in items if m != EXTERNAL]
    inline_items = [(n, m) for n, m in items if m == EXTERNAL]
    if mode == "inline":
        inline_items, parallel_items = items, []

    shards: List[ScopeShard] = []
    for name, module in inline_items:
        shard = _run_inline(name, module, registry, opts)
        on_shard(shard)
        shards.append(shard)
    if parallel_items:
        if mode == "subprocess":
            with ThreadPoolExecutor(max_workers=max(1, opts.jobs)) as tp:
                futs = {tp.submit(_run_subprocess, n, m, opts): (n, m)
                        for n, m in parallel_items}
                got = {}
                for fut in as_completed(futs):
                    shard = fut.result()
                    on_shard(shard)
                    got[shard.scope] = shard
            shards.extend(got[n] for n, _ in parallel_items if n in got)
        else:
            shards.extend(_run_pool(parallel_items, opts, on_shard))

    doc = merge_shards(shards, context_extra=context_extra, run_id=run_id)
    if out_dir:
        write_json(doc, os.path.join(out_dir, "merged.json"))
        log.info("wrote %s (%d records from %d shards)",
                 os.path.join(out_dir, "merged.json"),
                 len(doc["benchmarks"]), len(shards))
        _append_history(opts.results_dir, doc, run_id,
                        tag=opts.history_tag)
    return RunResult(doc=doc, shards=shards, run_id=run_id, out_dir=out_dir)


# ---------------------------------------------------------------------------
# standalone worker CLI (the subprocess-isolation entries)
# ---------------------------------------------------------------------------

def _worker_main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.core.orchestrate")
    ap.add_argument("--worker", action="store_true",
                    help="scope-grain worker: run one scope module")
    ap.add_argument("--worker-plan", action="store_true",
                    help="plan-grain worker: run a bin of instances")
    ap.add_argument("--module", help="[--worker] scope module to run")
    ap.add_argument("--out", help="[--worker] output document path")
    ap.add_argument("--items-json",
                    help="[--worker-plan] JSON file of plan-item metas")
    ap.add_argument("--spool",
                    help="[--worker-plan] instance-shard output directory")
    ap.add_argument("--filter", default=".*")
    ap.add_argument("--run-json", default="{}")
    ap.add_argument("--flags-json", default="{}")
    ns = ap.parse_args(argv)

    if ns.worker_plan:
        if not (ns.items_json and ns.spool):
            ap.error("--worker-plan requires --items-json and --spool")
        with open(ns.items_json) as f:
            items = json.load(f)
        return run_plan_items(items, RunOptions(**json.loads(ns.run_json)),
                              json.loads(ns.flags_json), ns.spool)

    if not (ns.worker and ns.module and ns.out):
        ap.error("need --worker with --module/--out, or --worker-plan")
    try:
        doc = run_one_scope(ns.module,
                            RunOptions(**json.loads(ns.run_json)),
                            ns.filter, json.loads(ns.flags_json))
    except Exception:  # noqa: BLE001 - report, don't look like a crash
        # a clean Python failure is an ERROR shard, not a CRASHED one —
        # write the traceback so the parent can tell them apart
        write_json({"worker_error": traceback.format_exc(limit=6)}, ns.out)
        return 3
    write_json(doc, ns.out)
    return 0


if __name__ == "__main__":
    sys.exit(_worker_main())
