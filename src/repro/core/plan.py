"""Work-plan layer — benchmark instances as the schedulable unit.

The paper's run stage (Fig. 2(d)) treats each scope as an opaque unit; the
orchestrator originally did too, so one slow scope serialized the tail of a
parallel run and a crashing benchmark poisoned its whole scope's shard.
Continuous-benchmarking systems (exaCB's incremental collections, ROOT's
continuous performance framework) schedule and cache at the granularity of
individual benchmark *runs*.  This module is that regranularization:

  * :func:`build_plan` enumerates a configured/registered
    :class:`~repro.core.scope.ScopeManager` + registry into addressable
    *benchmark instances* — ``(scope, family, arg-set)`` triples;
  * every :class:`PlanItem` carries a **stable instance ID** (derived only
    from the instance name, so it is identical across runs — the property
    that makes ``--resume`` and shard caching possible) and an optional
    **cost hint** pulled from a prior baseline/run document
    (:func:`load_cost_hints`);
  * :meth:`Plan.bins` packs items across workers with greedy
    longest-processing-time (LPT) using the cost hints, so a known-slow
    instance starts first instead of landing last on a busy worker.

The orchestrator (:mod:`repro.core.orchestrate`) schedules plan items when
``--shard-grain benchmark`` is active, and still derives its scope-grained
work list from :func:`scope_worklist` otherwise.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import statistics
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .logging import get_logger

log = get_logger("plan")

#: Predicted seconds for an instance with no cost hint and no prior data.
DEFAULT_COST = 1.0


def instance_id(name: str) -> str:
    """Stable, filesystem-safe ID for a benchmark instance name.

    A readable sanitized prefix plus a short digest of the *exact* name —
    sanitizing alone could collide (``a/b:1`` vs ``a/b_1``), the digest
    restores uniqueness while staying deterministic across runs.
    """
    safe = re.sub(r"[^A-Za-z0-9._-]+", "_", name).strip("_")[:80]
    digest = hashlib.sha1(name.encode("utf-8")).hexdigest()[:8]
    return f"{safe}-{digest}"


@dataclass(frozen=True)
class PlanItem:
    """One addressable benchmark instance: (scope, family, params).

    ``params`` is the instance's typed parameter point as (axis, value)
    pairs in axis order — its canonical JSON is recorded in the
    manifest, so instances stay addressable by parameter, not just by
    name.  ``arg_set`` keeps the int-valued axes as a tuple (the legacy
    view; identical to the old arg tuples for int-only families).
    """

    instance_id: str
    name: str                      # GB instance name, e.g. "example/saxpy/n:256"
    scope: str
    family: str                    # registered family name, e.g. "example/saxpy"
    module: str                    # scope module ("<external>" → inline only)
    arg_set: Tuple[int, ...]
    params: Tuple[Tuple[str, Any], ...] = ()
    cost: Optional[float] = None   # predicted seconds (None → plan default)

    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    def meta(self) -> Dict[str, Any]:
        return {
            "instance_id": self.instance_id,
            "name": self.name,
            "scope": self.scope,
            "family": self.family,
            "module": self.module,
            "arg_set": list(self.arg_set),
            "params": self.params_dict(),
            "cost": self.cost,
        }

    @classmethod
    def from_meta(cls, m: Dict[str, Any]) -> "PlanItem":
        return cls(instance_id=m["instance_id"], name=m["name"],
                   scope=m["scope"], family=m["family"], module=m["module"],
                   arg_set=tuple(m.get("arg_set", ())),
                   params=tuple((m.get("params") or {}).items()),
                   cost=m.get("cost"))


@dataclass
class Plan:
    """An ordered list of benchmark instances plus cost bookkeeping.

    Item order is the *document order*: merging instance shards in plan
    order reproduces exactly the benchmark sequence an inline scope-grained
    run emits, which is what keeps ``merged.json`` deterministic across
    ``--jobs``/``--shard-grain`` settings.
    """

    items: List[PlanItem] = field(default_factory=list)
    default_cost: float = DEFAULT_COST

    def cost_of(self, item: PlanItem) -> float:
        return item.cost if item.cost is not None else self.default_cost

    def total_cost(self) -> float:
        return sum(self.cost_of(i) for i in self.items)

    def by_id(self) -> Dict[str, PlanItem]:
        return {i.instance_id: i for i in self.items}

    def scopes(self) -> List[str]:
        out: List[str] = []
        for i in self.items:
            if i.scope not in out:
                out.append(i.scope)
        return out

    def bins(self, jobs: int,
             items: Optional[Sequence[PlanItem]] = None
             ) -> List[List[PlanItem]]:
        """Greedy LPT packing of ``items`` (default: all) into ``jobs`` bins.

        Deterministic: ties broken by plan position; within each bin the
        plan order is restored so workers execute (and stream shards) in
        document order.  Empty bins are dropped.
        """
        items = list(self.items if items is None else items)
        n = max(1, int(jobs))
        index = {i.instance_id: k for k, i in enumerate(items)}
        order = sorted(items,
                       key=lambda i: (-self.cost_of(i), index[i.instance_id]))
        loads = [0.0] * n
        bins: List[List[PlanItem]] = [[] for _ in range(n)]
        for item in order:
            k = min(range(n), key=lambda j: (loads[j], j))
            bins[k].append(item)
            loads[k] += self.cost_of(item)
        for b in bins:
            b.sort(key=lambda i: index[i.instance_id])
        return [b for b in bins if b]


def scope_worklist(mgr) -> List[Tuple[str, str]]:
    """(name, module) for every enabled+available scope, in load order.

    The scope-grained orchestrator work list (the old
    ``ScopeManager.dispatchable()``); module names are re-imported by
    workers, ``"<external>"`` scopes must run inline.
    """
    return [(s.scope.name, s.module) for s in mgr.scopes()
            if s.enabled and s.available]


def build_plan(mgr, registry, pattern: str = ".*",
               cost_hints: Optional[Dict[str, float]] = None,
               param_filter: Optional[Dict[str, List[str]]] = None) -> Plan:
    """Enumerate the registered benchmarks into an ordered instance plan.

    ``mgr`` must be loaded/configured/registered.  Families are selected
    per scope with ``registry.filter`` (same semantics as a scope-grained
    run: a family whose name or any instance matches runs *all* its
    instances), then expanded instance by instance in sweep order.
    ``param_filter`` (the ``--param key=value`` selection) prunes at the
    *instance* level: only points whose typed parameters match are
    planned.  Duplicate instance names — possible across families even
    though each family rejects duplicate points — are a hard error here,
    before they can collide as plan-ID duplicates.
    """
    from .benchmark import match_params
    hints = cost_hints or {}
    items: List[PlanItem] = []
    seen: Dict[str, str] = {}
    for scope_name, module in scope_worklist(mgr):
        for bench in registry.filter(pattern, scopes=[scope_name]):
            for name, params in bench.instances():
                if not match_params(params, param_filter):
                    continue
                if name in seen:
                    raise ValueError(
                        f"duplicate benchmark instance name {name!r} "
                        f"(families {seen[name]!r} and {bench.name!r})")
                seen[name] = bench.name
                items.append(PlanItem(
                    instance_id=instance_id(name),
                    name=name, scope=scope_name, family=bench.name,
                    module=module, arg_set=params.int_values(),
                    params=tuple(params.items()),
                    cost=hints.get(name),
                ))
    default = DEFAULT_COST
    known = [i.cost for i in items if i.cost is not None]
    if known:
        default = statistics.median(known)
    return Plan(items=items, default_cost=default)


def load_cost_hints(path: str) -> Dict[str, float]:
    """Per-instance predicted seconds from a prior baseline/run document.

    Two sources, best first:

      * a run directory with a ``manifest.json`` — the recorded wall
        duration of each completed instance (exactly what LPT wants);
      * any GB-JSON document / run directory — mean per-iteration seconds
        per ``run_name`` (a *relative* proxy: slow instances still sort
        ahead of fast ones even though calibration hides absolute cost).
    """
    manifest = os.path.join(path, "manifest.json") if os.path.isdir(path) \
        else None
    if manifest and os.path.exists(manifest):
        with open(manifest) as f:
            doc = json.load(f)
        out: Dict[str, float] = {}
        for entry in doc.get("items", []):
            dur = entry.get("duration_s")
            if entry.get("status") == "ok" and dur:
                out[entry["name"]] = float(dur)
        if out:
            return out
    from .baseline import collect_stats, load_document
    stats = collect_stats(load_document(path))
    return {name: st.mean for name, st in stats.items() if st.has_times}
