"""Instance fingerprints — the identity key of incremental benchmarking.

exaCB's premise is that a benchmark collection at scale must be
*incremental*: re-measure an instance only when something that could
change its number changed.  This module computes that "something" as a
deterministic, environment-insensitive digest per benchmark instance:

  * the family **body** and **fixture** source (captured at registration,
    :mod:`repro.core.registry`), plus the ``set_sync`` fence source and
    the canonical forms of the ``set_meters`` / ``set_tunable``
    declarations;
  * the instance's **canonical params JSON** (:meth:`Params.canonical`);
  * the transitive ``repro.kernels.*`` **module sources** the family
    imports (resolved from the import statements in the body/fixture
    source — the mxu/nn scopes import their Pallas kernels inside the
    fixture, so a kernel edit must re-measure every family driving it);
  * the **active tuned.json artifact** for the family's tunable kernel
    (:mod:`repro.kernels.tuning` — shipping new tuned blocks changes
    what runs);
  * the **jax / jaxlib versions** (an XLA upgrade re-measures everything).

Nothing host-specific enters the digest — no paths, hostnames, env vars
or timestamps — so the same checkout produces the same fingerprint on
every machine; *machine* identity is the separate sysinfo digest
(:func:`repro.core.sysinfo.context_digest`).  The pair (fingerprint,
sysinfo) decides freshness: ``repro run --since`` and ``repro ci`` skip
an instance when its current fingerprint already has a history record
on this machine (docs/continuous-benchmarking.md).
"""
from __future__ import annotations

import ast
import hashlib
import inspect
import json
import os
import textwrap
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .benchmark import Benchmark, Params
from .logging import get_logger

log = get_logger("fingerprint")

#: Bump when the digest recipe changes — old fingerprints then never
#: match, so every instance re-measures once (safe, conservative).
FINGERPRINT_VERSION = 1

#: Package whose modules are treated as measured-code dependencies.
KERNEL_PACKAGE = "repro.kernels"

#: Hex digest length kept on history records (64 bits of sha256).
DIGEST_LEN = 16

# freshness classifications (coverage table, delta planning)
FRESH = "fresh"      # latest record carries the current fingerprint
STALE = "stale"      # recorded before, but under a different fingerprint
NEVER = "never"      # no record for this instance on this machine


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


# ---------------------------------------------------------------------------
# transitive repro.kernels.* source discovery
# ---------------------------------------------------------------------------

def _kernels_root() -> str:
    """Filesystem root of the kernels package (no kernel import needed)."""
    import repro
    return os.path.join(os.path.dirname(os.path.abspath(repro.__file__)),
                        "kernels")


def _module_file(module: str) -> Optional[str]:
    """Source file of a ``repro.kernels.*`` module, resolved on disk.

    Pure path resolution — importing kernel modules here would pull JAX
    into every fingerprint computation.
    """
    if module == KERNEL_PACKAGE:
        rel: List[str] = []
    elif module.startswith(KERNEL_PACKAGE + "."):
        rel = module[len(KERNEL_PACKAGE) + 1:].split(".")
    else:
        return None
    base = os.path.join(_kernels_root(), *rel)
    for cand in (base + ".py", os.path.join(base, "__init__.py")):
        if os.path.isfile(cand):
            return cand
    return None


def _module_source(module: str) -> Optional[str]:
    path = _module_file(module)
    if path is None:
        return None
    try:
        with open(path, encoding="utf-8") as f:
            return f.read()
    except OSError:
        return None


def _imports_of(source: str, package: str = "") -> List[str]:
    """Absolute module names imported by ``source``.

    ``package`` resolves relative imports (``from .ops import matmul``
    inside ``repro.kernels.matmul`` → ``repro.kernels.matmul.ops``).
    ``from X import Y`` contributes both ``X`` and ``X.Y`` — Y may be a
    submodule (``from repro.kernels import matmul``) or a function; the
    non-module candidate simply resolves to no file later.
    """
    try:
        # function sources captured off a registry arrive indented
        tree = ast.parse(textwrap.dedent(source))
    except SyntaxError:
        return []
    out: List[str] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            out.extend(alias.name for alias in node.names)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                parts = package.split(".") if package else []
                if node.level <= len(parts):
                    base = ".".join(parts[:len(parts) - node.level + 1])
                else:
                    continue
            else:
                base = node.module or ""
            if node.module and node.level:
                base = f"{base}.{node.module}" if base else node.module
            if base:
                out.append(base)
                out.extend(f"{base}.{alias.name}" for alias in node.names)
    return out


def kernel_dependencies(sources: Iterable[Optional[str]]) -> List[str]:
    """Transitive ``repro.kernels.*`` modules reachable from ``sources``.

    Seeds are import statements found in the given source texts (family
    body and fixture); the closure follows imports *inside* the kernels
    package (``ops.py`` → ``kernel.py`` → ``tuning``), so editing any
    file a kernel is built from changes every dependent fingerprint.
    Returns sorted module names.
    """
    seen: Dict[str, Optional[str]] = {}
    frontier: List[Tuple[str, str]] = []   # (module, its package context)
    for src in sources:
        if not src:
            continue
        for mod in _imports_of(src):
            if mod.startswith(KERNEL_PACKAGE):
                frontier.append((mod, ""))
    while frontier:
        module, _pkg = frontier.pop()
        if not module.startswith(KERNEL_PACKAGE) or module in seen:
            continue
        src = _module_source(module)
        seen[module] = src
        if src is None:
            continue
        path = _module_file(module) or ""
        package = module if path.endswith("__init__.py") \
            else module.rsplit(".", 1)[0]
        for mod in _imports_of(src, package=package):
            if mod.startswith(KERNEL_PACKAGE) and mod not in seen:
                frontier.append((mod, package))
    return sorted(m for m, src in seen.items() if src is not None)


def _kernel_sources_digest(sources: Iterable[Optional[str]]) -> str:
    parts = []
    for module in kernel_dependencies(sources):
        parts.append(f"{module}\n{_module_source(module) or ''}")
    return _sha("\n\x00".join(parts)) if parts else ""


# ---------------------------------------------------------------------------
# per-family inputs
# ---------------------------------------------------------------------------

def _sync_source(bench: Benchmark) -> str:
    """Source of the family's sync fence (``set_sync`` stores only the
    callable, so derive the text here; a builtin/dynamic fence degrades
    to its qualified name — still deterministic)."""
    fn = bench.sync_fn
    if fn is None:
        return ""
    try:
        return inspect.getsource(fn)
    except (OSError, TypeError):
        return getattr(fn, "__qualname__", repr(type(fn).__name__))


def _meters_canonical(bench: Benchmark) -> str:
    if not bench.meters:
        return ""
    return json.dumps([m if isinstance(m, str) else type(m).__name__
                       for m in bench.meters])


def _tunable_canonical(bench: Benchmark) -> str:
    t = bench.tunable
    if t is None:
        return ""
    return json.dumps({
        "kernel": t.kernel,
        "space": sorted(p.canonical() for p in t.space.points()),
        "instance": list(t.instance),
    }, sort_keys=True)


def _tuned_artifact(bench: Benchmark) -> str:
    """Canonical JSON of the *active* tuned config for the family's
    kernel ('' when untunable or no artifact is active).  Content-based:
    where the artifact lives (``REPRO_TUNED_DIR``) never enters the
    digest, what it says does."""
    if bench.tunable is None:
        return ""
    from repro.kernels import tuning
    try:
        payload = tuning.load_tuned(bench.tunable.kernel)
    except Exception:  # noqa: BLE001 - unreadable artifact == no artifact
        payload = None
    if not payload:
        return ""
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def _stack_versions() -> Dict[str, str]:
    out = {"jax": "", "jaxlib": ""}
    try:
        import jax
        out["jax"] = getattr(jax, "__version__", "")
    except Exception:  # noqa: BLE001 - fingerprints must not require jax
        return out
    try:
        import jaxlib
        out["jaxlib"] = getattr(jaxlib, "__version__", "")
    except Exception:  # noqa: BLE001
        pass
    return out


def family_inputs(bench: Benchmark) -> Dict[str, str]:
    """The labeled digest inputs of one family (docs/tests introspect
    this to see *which* component moved a fingerprint)."""
    versions = _stack_versions()
    return {
        "version": str(FINGERPRINT_VERSION),
        "body": bench.source or f"<uncapturable:{bench.name}>",
        "fixture": bench.fixture_source or "",
        "sync": _sync_source(bench),
        "meters": _meters_canonical(bench),
        "tunable": _tunable_canonical(bench),
        "kernels": _kernel_sources_digest([bench.source,
                                           bench.fixture_source]),
        "tuned": _tuned_artifact(bench),
        "jax": versions["jax"],
        "jaxlib": versions["jaxlib"],
    }


def family_digest(bench: Benchmark) -> str:
    return _sha(json.dumps(family_inputs(bench), sort_keys=True))


def instance_fingerprint(bench: Benchmark, params: Params,
                         family_dig: Optional[str] = None) -> str:
    """The fingerprint of one (family, parameter point) instance."""
    family_dig = family_dig or family_digest(bench)
    return _sha(f"{family_dig}:{params.canonical()}")[:DIGEST_LEN]


def registry_fingerprints(benches: Sequence[Benchmark]
                          ) -> Dict[str, str]:
    """Instance name → fingerprint for every instance of ``benches``.

    This is the map a run carries in its document context
    (``context["fingerprints"]``) so history records stay reproducible
    from the run artifacts alone.
    """
    out: Dict[str, str] = {}
    for bench in benches:
        fam = family_digest(bench)
        for name, params in bench.instances():
            out[name] = instance_fingerprint(bench, params, fam)
    return out


# ---------------------------------------------------------------------------
# freshness: fingerprints × history
# ---------------------------------------------------------------------------

def latest_measurements(records: Sequence[Dict[str, Any]],
                        sysinfo: Optional[str] = None
                        ) -> Dict[str, Dict[str, Any]]:
    """Newest *measured* history record per instance name.

    Replayed (``cached``) records and autotuning trials (``tag:
    "tune"``) are not measurements of the current code — they never
    refresh an instance.  ``sysinfo`` restricts to one machine/stack
    digest (records from other machines can't vouch for this one).
    """
    out: Dict[str, Dict[str, Any]] = {}
    for rec in records:
        if rec.get("cached") or rec.get("tag") == "tune":
            continue
        if sysinfo is not None and rec.get("sysinfo") != sysinfo:
            continue
        name = rec.get("name")
        if name:
            out[name] = rec
    return out


def classify(fingerprint: str, rec: Optional[Dict[str, Any]],
             since: str = "") -> str:
    """FRESH / STALE / NEVER for one instance vs its latest record.

    A record only counts as fresh when it actually measured something
    (``mean_s`` present, no errors), its fingerprint matches, and — when
    ``since`` is a non-empty ISO prefix — it is recent enough.
    """
    if rec is None:
        return NEVER
    if rec.get("fingerprint") != fingerprint:
        return STALE
    if rec.get("mean_s") is None or rec.get("errors"):
        return STALE
    if since and str(rec.get("ts", "")) < since:
        return STALE
    return FRESH


def delta_split(plan_items: Sequence[Any], fingerprints: Dict[str, str],
                records: Sequence[Dict[str, Any]], sysinfo: str,
                since: str = ""
                ) -> Tuple[List[Any], Dict[str, Dict[str, Any]]]:
    """Split plan items into (to-run, cached) for a ``--since`` delta run.

    ``cached`` maps instance_id → the latest history record vouching for
    the skipped instance; the orchestrator materializes those into the
    merged document as ``cached: true`` records so reports stay complete.
    """
    latest = latest_measurements(records, sysinfo=sysinfo)
    pending: List[Any] = []
    cached: Dict[str, Dict[str, Any]] = {}
    for item in plan_items:
        fp = fingerprints.get(item.name, "")
        rec = latest.get(item.name)
        if fp and classify(fp, rec, since=since) == FRESH:
            cached[item.instance_id] = rec
        else:
            pending.append(item)
    return pending, cached


def registered_benches(scope_modules: Optional[List[str]] = None
                       ) -> List[Benchmark]:
    """Load + register the benchmark scopes; return every family.

    The coverage consumers (``repro store status --coverage``, the
    dashboard's ``/api/coverage``) run outside the normal run startup
    sequence, so this replays its registration steps against the
    process-global registry with default flag values.  Heavy (imports
    JAX via the scope modules) — call lazily, cache the result.
    """
    from .hooks import HOOKS
    from .registry import REGISTRY
    from .scope import ScopeManager

    REGISTRY.reset()
    mgr = ScopeManager()
    mgr.load(scope_modules)
    rc = HOOKS.run_pre_parse()
    if rc is None:
        rc = HOOKS.run_post_parse()
    if rc is not None:
        raise RuntimeError(f"scope init hook requested exit ({rc})")
    mgr.register_all()
    return REGISTRY.all()


def coverage(benches: Sequence[Benchmark],
             records: Sequence[Dict[str, Any]],
             sysinfo: Optional[str] = None) -> Dict[str, Any]:
    """Per-scope freshness coverage — the ``repro store status
    --coverage`` table and the dashboard's staleness panel.

    ``sysinfo`` defaults to the newest record's digest (the machine the
    history was last written from); with no records at all, everything
    is ``never``.
    """
    if sysinfo is None:
        for rec in reversed(records):
            if rec.get("sysinfo"):
                sysinfo = rec["sysinfo"]
                break
    latest = latest_measurements(records, sysinfo=sysinfo)
    scopes: Dict[str, Dict[str, int]] = {}
    stale_names: List[str] = []
    for bench in benches:
        fam = family_digest(bench)
        row = scopes.setdefault(bench.scope,
                                {FRESH: 0, STALE: 0, NEVER: 0})
        for name, params in bench.instances():
            fp = instance_fingerprint(bench, params, fam)
            state = classify(fp, latest.get(name))
            row[state] += 1
            if state != FRESH:
                stale_names.append(name)
    totals = {k: sum(row[k] for row in scopes.values())
              for k in (FRESH, STALE, NEVER)}
    return {"sysinfo": sysinfo or "", "scopes": scopes, "totals": totals,
            "instances": totals[FRESH] + totals[STALE] + totals[NEVER],
            "pending": sorted(stale_names)}
