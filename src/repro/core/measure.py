"""Pluggable measurement meters — the run stage's observation layer.

The paper's core claim is that "developing and defining accurate
performance measurements is necessary at all levels of the system
hierarchy" (§I).  The runner used to hardwire one measurement — a bare
``perf_counter`` around the batch, with ``cpu_time`` emitted as a copy
of ``real_time`` and no fence over JAX's async dispatch, so a body that
never blocked measured *enqueue* cost, not compute.  This module turns
measurement into a provider API the runner drives around every warm,
calibration and repetition batch:

  * :class:`Meter` — the provider protocol: ``begin(state)`` before the
    batch body runs, ``end(state) -> {metric: value}`` after, plus an
    optional per-*sample* channel ``observe(state, sample)`` fed by
    bodies calling ``state.observe({...})`` (one serving request's
    latency, one step's queue depth — events inside the batch window
    that begin/end cannot see).  Two metric keys are reserved and
    consumed by the runner for the canonical GB record fields
    (:data:`WALL_TIME`, :data:`CPU_TIME`); everything else a meter
    returns flows into the record as inlined GB counters, so
    ScopePlot/report pick new metrics up with zero schema work;
  * :class:`MeterStack` — an ordered set of meters built once per
    benchmark instance (``MeterStack.build``), begun in order and ended
    in reverse order around each batch, with derived roofline counters
    (``flops_per_second``) computed where the primitives allow;
  * :class:`WallClockMeter` — the primary clock.  Installs a per-family
    ``sync(ctx)`` fence into the state's timer-stop path so async
    dispatch is *fenced before the clock stops*: the default fence is
    ``jax.block_until_ready`` over the batch's declared deliverables
    (``state.deliver(out)``), falling back to the fixture context.
    Families override it with ``bench.set_sync(fn)`` (a no-op fence
    opts a host-synchronous family out);
  * :class:`CpuTimeMeter` — ``time.process_time`` over the same timed
    window the wall clock measures, making ``cpu_time`` a real
    measurement; the wall/CPU gap is the dispatch/device-wait signal;
  * :class:`CostModelMeter` — static cost-model counters (``flops``,
    ``bytes_accessed``, ``arithmetic_intensity``) derived once per
    instance from the fixture's jitted callable: optimized-HLO analysis
    through :mod:`repro.roofline.hlo` (loop-trip-aware, exact for
    ``dot``), with ``Lowered.cost_analysis()`` as the fallback for
    quantities the analyzer cannot see (elementwise FLOPs).  Combined
    with the wall clock it emits achieved ``flops_per_second`` on every
    record for free;
  * :class:`LatencyMeter` — the observe-channel consumer (``--meters
    latency``): collects per-request ``ttft_s``/``latency_s`` and
    per-step ``queue_depth`` samples from ``state.observe`` and emits
    tail percentiles (``latency_p50_s`` … ``latency_p999_s``,
    ``ttft_p50_s``/``ttft_p99_s``), ``queue_depth_mean``, and
    ``goodput_rps`` — requests per second completed within the SLO
    (``--slo-ms``; every completed request counts when no SLO is set)
    plus ``slo_attainment`` when one is.  Means are the wrong statistic
    for serving traffic; this meter is why per-sample delivery exists.

Meter sets are selected per run (``--meters wall,cpu,costmodel`` →
``RunOptions.meters``) or per family (``bench.set_meters(...)``); the
wall and CPU meters are always present — they are the time sources the
records are built from, so a selection like ``--meters costmodel``
adds to the core set rather than silently reverting ``cpu_time`` to a
copy of ``real_time``.
"""
from __future__ import annotations

import sys
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .logging import get_logger

log = get_logger("measure")

#: Reserved metric keys consumed by the runner for canonical GB record
#: fields (seconds per *batch*); everything else becomes a counter.
WALL_TIME = "real_time_s"
CPU_TIME = "cpu_time_s"

#: The meter set a run uses when neither the family nor the run options
#: select one.  ``cpu`` is on by default: ``cpu_time`` has been a silent
#: copy of ``real_time`` for long enough.
DEFAULT_METERS = ("wall", "cpu")


#: Families already warned about a weak (inputs-only) default fence.
_WEAK_FENCE_WARNED: set = set()


def default_sync(state, family: str = "") -> None:
    """Fence async dispatch before the clock stops.

    Blocks on the batch's declared deliverables (``state.deliver(out)``
    inside the timed loop), falling back to the fixture context.  Only
    fences when JAX is already loaded in this process — if no code
    imported jax, nothing async was dispatched, and a numpy-only run
    must not pay a jax import inside its timed region.

    The fixture fallback is a *weak* fence: blocking on input arrays
    does not wait for dispatched work that consumes them.  A family
    whose fixture holds jax arrays but whose body never delivered
    anything is warned once — its numbers are still enqueue-timed
    until it declares deliverables (or a ``set_sync`` fence).
    """
    target = state.deliverables
    fallback = target is None
    if fallback:
        target = state.fixture
    if target is None:
        return
    jax = sys.modules.get("jax")
    if jax is None:
        return
    if fallback and family not in _WEAK_FENCE_WARNED and any(
            isinstance(leaf, jax.Array)
            for leaf in jax.tree_util.tree_leaves(target)):
        _WEAK_FENCE_WARNED.add(family)
        log.warning(
            "benchmark %s: body never declared deliverables "
            "(state.deliver) — the default fence can only block on the "
            "fixture's *inputs*, which does not wait for dispatched "
            "work, so real_time may be enqueue cost; declare "
            "deliverables or set_sync (docs/measurement.md)", family)
    jax.block_until_ready(target)


def fixture_call(state) -> Optional[Tuple[Callable, tuple]]:
    """The ``(callable, args)`` convention of fixture contexts.

    Builtin fixtures return ``(jitted_fn, *operands)``; meters that need
    the traced computation (cost model) recover it from that shape.
    ``None`` when the fixture doesn't follow the convention.
    """
    ctx = state.fixture
    if isinstance(ctx, tuple) and ctx and callable(ctx[0]):
        return ctx[0], tuple(ctx[1:])
    return None


class Meter:
    """Measurement provider protocol.

    ``begin(state)`` runs immediately before the batch body,
    ``end(state)`` immediately after; ``end`` returns ``{metric:
    value}``.  ``observe(state, sample)`` is the per-*sample* channel:
    the stack routes every ``state.observe({...})`` the body makes to
    every meter, so a meter can aggregate events (requests, steps)
    that happen *inside* the batch window.  ``bind(bench)`` is called
    once when the stack is built so a meter can read per-family
    configuration (sync hook, manual-time mode); ``configure(opts)``
    hands it the run options (``--slo-ms`` and friends).  Meters must
    not mutate the measurement itself — the wall meter owns the clock,
    and observe implementations must read timestamps from the state or
    the sample payload, never from host clocks (repro lint SCOPE108).
    """

    name = "meter"

    def bind(self, bench) -> None:  # pragma: no cover - default no-op
        pass

    def configure(self, opts) -> None:  # pragma: no cover - default no-op
        """Run-level configuration (a ``RunOptions``), once at build."""

    def prepare(self, state) -> None:  # pragma: no cover - default no-op
        """Once per instance, before the warm batch — expensive one-time
        analysis belongs here so it cannot pollute ``compile_time_s``."""

    def begin(self, state) -> None:  # pragma: no cover - default no-op
        pass

    def observe(self, state, sample) -> None:  # pragma: no cover - no-op
        """One per-sample event from ``state.observe`` (a mapping)."""

    def end(self, state) -> Dict[str, float]:
        return {}


class WallClockMeter(Meter):
    """The primary clock: the state's timed window, device-fenced.

    The state's timer stops inside ``keep_running`` (before the body
    returns), so the fence cannot run after the batch — instead the
    meter installs the family's ``sync(ctx)`` hook into the state and
    the state runs it *before capturing the stop timestamp*.  Manual
    -time families report their accumulated ``set_iteration_time``
    instead, unfenced (the body already owns its timing).
    """

    name = "wall"

    def __init__(self, sync: Optional[Callable] = None):
        self._ctor_sync = sync           # explicit ctor fence always wins
        self._sync: Optional[Callable] = sync
        self._manual = False

    def bind(self, bench) -> None:
        # re-resolved on every bind: a meter instance shared across
        # families (set_meters) must pick up each family's own fence
        self._manual = bench.use_manual_time
        if self._ctor_sync is not None:
            self._sync = self._ctor_sync
        elif bench.sync_fn is not None:
            self._sync = bench.sync_fn
        else:
            family = bench.name
            self._sync = lambda state: default_sync(state, family)

    def begin(self, state) -> None:
        # manual-time families own their timing (set_iteration_time):
        # the auto timer window is unused, so fencing it would only
        # burn time and mislabel the family as unfenced
        if not self._manual:
            state._sync = self._sync or default_sync

    def end(self, state) -> Dict[str, float]:
        t = state.manual_elapsed if self._manual else state.elapsed
        return {WALL_TIME: t}


class CpuTimeMeter(Meter):
    """Process CPU seconds over the wall clock's timed window.

    Reads the state's CPU-time window (accumulated alongside the wall
    window, so ``pause_timing`` excludes the same sections from both).
    Device/dispatch waits burn wall time but almost no CPU — the gap
    between the two is the dispatch-overhead signal; CPU above wall
    means multi-threaded host compute.
    """

    name = "cpu"

    def end(self, state) -> Dict[str, float]:
        return {CPU_TIME: state.cpu_elapsed}


class CostModelMeter(Meter):
    """Static cost-model counters from the fixture's jitted callable.

    Lowers the fixture's ``(fn, *args)`` once per parameter point and
    derives per-call ``flops`` / ``bytes_accessed``:

      * primary: optimized-HLO text through
        :func:`repro.roofline.hlo.analyze_hlo` — loop-trip-aware and
        exact for ``dot`` (2·out·contract);
      * fallback: ``Lowered.cost_analysis()`` for quantities the text
        analyzer reports as zero (elementwise FLOPs live there).

    A family whose fixture doesn't follow the convention (or whose
    callable can't lower) contributes nothing — the meter degrades
    silently rather than failing the instance.  Results are cached per
    parameter point, so warm/calibration/repetition batches pay the
    analysis once.
    """

    name = "costmodel"

    def __init__(self):
        self._cache: Dict[str, Dict[str, float]] = {}
        self._family = ""

    def bind(self, bench) -> None:
        # part of the cache key: a meter instance shared across
        # families (set_meters) must not hand one family's flops to
        # another family whose point has the same axis values
        self._family = bench.name

    def _key(self, state) -> str:
        return f"{self._family}|{state.params.canonical()}"

    def prepare(self, state) -> None:
        # analyze before the warm batch is timed: lowering + compiling
        # for analysis must not inflate the instance's compile_time_s
        key = self._key(state)
        if key not in self._cache:
            self._cache[key] = self._analyze(state)

    def end(self, state) -> Dict[str, float]:
        key = self._key(state)
        if key not in self._cache:
            self._cache[key] = self._analyze(state)
        return dict(self._cache[key])

    def _analyze(self, state) -> Dict[str, float]:
        call = fixture_call(state)
        if call is None:
            return {}
        fn, args = call
        jax = sys.modules.get("jax")
        if jax is None:
            return {}
        try:
            lowered = fn.lower(*args) if hasattr(fn, "lower") \
                else jax.jit(fn).lower(*args)
        except Exception as e:  # noqa: BLE001 - degrade, don't fail the run
            log.debug("costmodel: %s would not lower: %s", state.params, e)
            return {}
        flops = 0.0
        nbytes = 0.0
        try:
            from repro.roofline.hlo import analyze_hlo
            stats = analyze_hlo(lowered.compile().as_text())
            flops, nbytes = stats.flops, stats.bytes_accessed
        except Exception as e:  # noqa: BLE001 - interpret-mode, AOT quirks
            log.debug("costmodel: HLO analysis failed for %s: %s",
                      state.params, e)
        if not flops or not nbytes:
            try:
                ca = lowered.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0] if ca else {}
                flops = flops or float(ca.get("flops") or 0.0)
                nbytes = nbytes or float(ca.get("bytes accessed") or 0.0)
            except Exception as e:  # noqa: BLE001
                log.debug("costmodel: cost_analysis failed for %s: %s",
                          state.params, e)
        out: Dict[str, float] = {}
        if flops:
            out["flops"] = flops
        if nbytes:
            out["bytes_accessed"] = nbytes
        if flops and nbytes:
            out["arithmetic_intensity"] = flops / nbytes
        return out


class LatencyMeter(Meter):
    """Tail-latency distribution counters from the per-sample channel.

    Consumes ``state.observe({...})`` samples the batch body delivers:

      * ``latency_s`` — one request's end-to-end latency (submit →
        last token delivered);
      * ``ttft_s`` — the same request's time to first token;
      * ``queue_depth`` — one engine step's queued + in-flight count.

    ``end`` reduces them to GB counters: ``latency_p50_s`` /
    ``latency_p90_s`` / ``latency_p99_s`` / ``latency_p999_s``,
    ``ttft_p50_s`` / ``ttft_p99_s``, ``queue_depth_mean``,
    ``requests_completed``, and ``goodput_rps`` — completed requests
    per second of batch wall time that met the SLO (``--slo-ms`` →
    ``RunOptions.slo_ms``; with no SLO every completed request counts).
    ``slo_attainment`` (fraction within SLO) appears only when an SLO
    is configured, so default-run records stay byte-stable.

    Percentiles are exact (:mod:`repro.core.quantile`) — per-batch
    sample counts are small; the module's P² streaming estimator is
    the documented escape hatch when they stop being small.  Samples
    observed across the iterations of one batch are merged with the
    order-invariant :func:`repro.core.quantile.combine`, so shard
    grain and worker count cannot change the counters.
    """

    name = "latency"

    def __init__(self, slo_ms: Optional[float] = None):
        self._ctor_slo = slo_ms          # explicit ctor SLO always wins
        self.slo_ms = slo_ms
        self._latency: List[List[float]] = []
        self._ttft: List[List[float]] = []
        self._depth: List[float] = []

    def configure(self, opts) -> None:
        if self._ctor_slo is None:
            self.slo_ms = getattr(opts, "slo_ms", None)

    def begin(self, state) -> None:
        # one bucket per iteration: samples merge order-invariantly in
        # end(), mirroring how shards merge across workers
        self._latency = [[]]
        self._ttft = [[]]
        self._depth = []

    def observe(self, state, sample) -> None:
        if "latency_s" in sample:
            self._latency[-1].append(float(sample["latency_s"]))
        if "ttft_s" in sample:
            self._ttft[-1].append(float(sample["ttft_s"]))
        if "queue_depth" in sample:
            self._depth.append(float(sample["queue_depth"]))

    def end(self, state) -> Dict[str, float]:
        from .quantile import combine, percentile, tail_percentiles
        out: Dict[str, float] = {}
        lat = combine(*self._latency)
        ttft = combine(*self._ttft)
        out.update(tail_percentiles(lat, prefix="latency_"))
        if ttft:
            out["ttft_p50_s"] = percentile(ttft, 0.50)
            out["ttft_p99_s"] = percentile(ttft, 0.99)
        if self._depth:
            out["queue_depth_mean"] = sum(self._depth) / len(self._depth)
        if lat:
            out["requests_completed"] = float(len(lat))
            slo_s = self.slo_ms / 1e3 if self.slo_ms is not None else None
            good = len(lat) if slo_s is None \
                else sum(1 for t in lat if t <= slo_s)
            span = state.manual_elapsed or state.elapsed
            if span > 0:
                out["goodput_rps"] = good / span
            if slo_s is not None:
                out["slo_attainment"] = good / len(lat)
        return out


#: Built-in meter registry: ``--meters`` names → factories.
METERS: Dict[str, Callable[[], Meter]] = {
    "wall": WallClockMeter,
    "cpu": CpuTimeMeter,
    "costmodel": CostModelMeter,
    "latency": LatencyMeter,
}


def validate_meter_name(name: str) -> str:
    """Raise ``ValueError`` (with the available set) unless ``name`` is
    a registered meter — the single check behind the CLI flag,
    ``set_meters`` registration, and stack build."""
    if name not in METERS:
        raise ValueError(
            f"unknown meter {name!r} (available: {', '.join(METERS)})")
    return name


def parse_meters(spec: str) -> List[str]:
    """``--meters wall,cpu,costmodel`` → validated name list.

    Raises ``ValueError`` on an unknown meter so the CLI can reject the
    flag before any benchmark runs.
    """
    names: List[str] = []
    for part in spec.split(","):
        name = part.strip()
        if not name:
            continue
        validate_meter_name(name)
        if name not in names:
            names.append(name)
    if not names:
        raise ValueError("--meters needs at least one meter name")
    return names


class MeterStack:
    """An ordered meter set driven around one batch.

    ``begin`` runs meters in order, ``end`` in reverse order (the wall
    meter is always first, so its clock brackets the others' reads as
    tightly as possible).  ``end`` merges every meter's metrics and adds
    derived roofline counters: with both a cost model and a wall time
    present, achieved ``flops_per_second`` comes for free.
    """

    def __init__(self, meters: Sequence[Meter]):
        self.meters = list(meters)

    @classmethod
    def build(cls, spec: Optional[Sequence[Any]], bench,
              run_opts: Optional[Any] = None) -> "MeterStack":
        """Resolve a meter spec (names, instances, factories) for one
        family.  The wall and CPU meters are mandatory and prepended
        when the spec omits them: the wall meter is the run's time
        source, and a missing CPU meter would silently revert
        ``cpu_time`` to a copy of ``real_time`` — the exact defect the
        meter layer exists to fix.  ``--meters``/``set_meters`` select
        the *opt-in* meters on top of that core.  ``run_opts`` (a
        :class:`repro.core.runner.RunOptions`, when available) lets
        meters pick up run-level settings like ``--slo-ms`` via
        :meth:`Meter.configure`.
        """
        meters: List[Meter] = []
        for item in (spec or DEFAULT_METERS):
            if isinstance(item, str):
                meters.append(METERS[validate_meter_name(item)]())
            elif isinstance(item, Meter):
                meters.append(item)
            elif callable(item):
                meters.append(item())
            else:
                raise TypeError(f"not a meter: {item!r}")
        if not any(isinstance(m, CpuTimeMeter) for m in meters):
            meters.insert(0, CpuTimeMeter())
        if not any(isinstance(m, WallClockMeter) for m in meters):
            meters.insert(0, WallClockMeter())
        for m in meters:
            m.bind(bench)
            if run_opts is not None:
                m.configure(run_opts)
        return cls(meters)

    def prepare(self, state) -> None:
        for m in self.meters:
            m.prepare(state)

    def begin(self, state) -> None:
        # route state.observe(...) samples to every meter in the stack
        state._observer = self._observe
        for m in self.meters:
            m.begin(state)

    def _observe(self, state, sample) -> None:
        for m in self.meters:
            m.observe(state, sample)

    def end(self, state) -> Dict[str, float]:
        metrics: Dict[str, float] = {}
        for m in reversed(self.meters):
            metrics.update(m.end(state))
        wall = metrics.get(WALL_TIME)
        flops = metrics.get("flops")
        if wall and flops:
            metrics["flops_per_second"] = \
                flops * max(state.iterations, 1) / wall
        return metrics
