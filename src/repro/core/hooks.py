"""Initialization hooks — paper §III-G.

Scopes may register arbitrary code to run (a) before CLI args are parsed,
(b) after args are parsed but before any benchmark executes.  Hooks run in
registration order; a hook returning a non-None int requests early exit with
that status (Example|Scope uses this to exit during initialization).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

Hook = Callable[[], Optional[int]]


class HookChain:
    def __init__(self) -> None:
        self._pre_parse: List[Tuple[str, Hook]] = []
        self._post_parse: List[Tuple[str, Hook]] = []

    def register_pre_parse(self, fn: Hook, owner: str = "core") -> None:
        self._pre_parse.append((owner, fn))

    def register_post_parse(self, fn: Hook, owner: str = "core") -> None:
        self._post_parse.append((owner, fn))

    def run_pre_parse(self) -> Optional[int]:
        return self._run(self._pre_parse)

    def run_post_parse(self) -> Optional[int]:
        return self._run(self._post_parse)

    @staticmethod
    def _run(chain: List[Tuple[str, Hook]]) -> Optional[int]:
        for _owner, fn in chain:
            rc = fn()
            if rc is not None:
                return rc
        return None

    def reset(self) -> None:
        self._pre_parse.clear()
        self._post_parse.clear()


HOOKS = HookChain()
