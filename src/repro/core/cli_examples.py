"""Copy-pasteable ``--help`` examples for every ``python -m repro`` command.

Kept as data (not inline strings) so ``tests/test_docs.py`` can assert
two things that otherwise rot silently: every example appears verbatim
in its subcommand's ``--help`` epilog, and every example still *parses*
against the current argument surface.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

# command → [(what it does, exact command line), ...]
EXAMPLES: Dict[str, List[Tuple[str, str]]] = {
    "run": [
        ("run every scope in 4 isolated workers; shards, merged.json and "
         "history land under results/<run-id>/",
         "python -m repro run --jobs 4 --results-dir results"),
        ("finish an interrupted run: completed instances are skipped",
         "python -m repro run --jobs 4 --results-dir results "
         "--resume 20260731T120000-42"),
        ("one scope, one benchmark family, plain GB-JSON to a file",
         "python -m repro run --enable-scope example "
         "--benchmark_filter example/saxpy --benchmark_out saxpy.json"),
        ("run only the bf16 points of every typed parameter space",
         "python -m repro run --param dtype=bf16 --jobs 2"),
        ("device-fenced wall time, real CPU time, and static "
         "flops/bytes_accessed counters on every record",
         "python -m repro run --meters wall,cpu,costmodel --jobs 2"),
        ("serve under open-loop Poisson load with tail-latency counters "
         "(p50/p99/p999, goodput against a 200 ms SLO) on every record",
         "python -m repro run --enable-scope serve --param arrival=poisson "
         "--meters wall,cpu,latency --slo-ms 200"),
        ("repetition statistics only, with throughput and meter "
         "counters carried onto the aggregate records",
         "python -m repro run --benchmark_repetitions 5 "
         "--aggregates-only"),
        ("gate against the windowed run history (exit 1 on regression)",
         "python -m repro run --jobs 2 --baseline results/history.jsonl"),
        ("store this run as the baseline for later gating",
         "python -m repro run --save-baseline results/baseline.json"),
        ("lint pre-flight: abort before anything is timed if a family "
         "provably mismeasures",
         "python -m repro run --lint --strict --jobs 2"),
        ("delta run: skip instances whose fingerprint (body/fixture/"
         "kernel source, params, tuned artifact, jax version) already "
         "has a measured record; replay them as cached",
         "python -m repro run --since --results-dir results"),
        ("delta run, but records older than Aug 1 don't count as fresh",
         "python -m repro run --since 2026-08-01 --jobs 2"),
    ],
    "plan": [
        ("print every benchmark instance with its predicted cost and "
         "LPT worker-bin assignment",
         "python -m repro plan --jobs 4"),
        ("use a prior run's measured durations as cost hints",
         "python -m repro plan --jobs 4 --costs results/20260731T120000-42"),
        ("plan only one backend's instances of the typed spaces",
         "python -m repro plan --param backend=pallas"),
        ("delta plan: print only what repro ci would re-measure now "
         "(fingerprint-fresh instances are pruned)",
         "python -m repro plan --since --results-dir results"),
    ],
    "ci": [
        ("per-commit gate: delta-plan against history, re-measure only "
         "fingerprint-stale instances, judge them against the pooled "
         "window, exit 1 on regression",
         "python -m repro ci --jobs 2 --results-dir results"),
        ("full sweep (no delta pruning) with a stricter gate",
         "python -m repro ci --full --threshold 0.05 --window 10"),
        ("gate one scope's bf16 instances, skipping the report render",
         "python -m repro ci --enable-scope mxu --param dtype=bf16 "
         "--no-report"),
    ],
    "tune": [
        ("screen + hill-climb the matmul block space under a 16-trial "
         "budget; the winner ships as the kernel's tuned.json default",
         "python -m repro tune mxu/matmul --budget 16 --seed 0"),
        ("spend the budget on configs a prior tune run measured cheapest",
         "python -m repro tune mxu/matmul --budget 8 "
         "--costs results/20260731T120000-42"),
        ("maximize the cost-model FLOP rate instead of minimizing wall "
         "time",
         "python -m repro tune mxu/matmul --objective flops_per_second "
         "--budget 12"),
        ("screening only: rank the axes by sensitivity without refining",
         "python -m repro tune nn/rmsnorm --strategy screening"),
        ("list every family that declares a tunable kernel space",
         "python -m repro tune --list"),
    ],
    "compare": [
        ("mean/stddev-aware diff of two runs (exit 1 on regression)",
         "python -m repro compare results/baseline.json "
         "results/20260731T120000-42"),
        ("diff the latest run against the windowed history baseline",
         "python -m repro compare results/history.jsonl "
         "results/20260731T120000-42 --threshold 0.05"),
        ("compare only the bf16 instances of two runs",
         "python -m repro compare results/baseline.json "
         "results/20260731T120000-42 --param dtype=bf16"),
    ],
    "lint": [
        ("static-analyze every enabled scope (AST + compile + registry "
         "tiers); exit 1 on error-severity findings",
         "python -m repro lint"),
        ("lint one scope, failing on warnings too",
         "python -m repro lint --scope example --strict"),
        ("machine-readable findings for CI",
         "python -m repro lint --format json --strict"),
        ("fast editor loop: AST/registry tiers only, one family",
         "python -m repro lint --no-compile --family example/saxpy"),
        ("run a single rule across every scope",
         "python -m repro lint --rules SCOPE201"),
        ("print the rule catalog",
         "python -m repro lint --list-rules"),
    ],
    "query": [
        ("every bf16 record in the run history, as a table",
         "python -m repro query --param dtype=bf16"),
        ("per-instance statistics for one family: mean/stddev and "
         "streaming P² percentiles over run means and counters",
         "python -m repro query --family mxu/matmul --aggregate "
         "--percentiles p50,p99,p999 --format json"),
        ("one machine's records in a date range, as verbatim history "
         "lines (byte-equivalent with or without the index)",
         "python -m repro query --sysinfo 3f2a9c1d --since 2026-08-01 "
         "--until 2026-08-07 --format jsonl"),
        ("prove the index changes cost, not answers",
         "python -m repro query --family mxu/matmul --no-store "
         "--format jsonl"),
    ],
    "store": [
        ("build/refresh the SQLite index (incremental: only bytes past "
         "the watermark are read)",
         "python -m repro store index --results-dir results"),
        ("drop and re-index from scratch (byte-deterministic)",
         "python -m repro store index --rebuild"),
        ("merge two lab machines' history shards into this store, "
         "deduplicating whole runs by (run-id, sysinfo digest)",
         "python -m repro store ingest lab-a.jsonl lab-b.jsonl"),
        ("index freshness, watermark and table counts",
         "python -m repro store status --format json"),
        ("per-scope fingerprint coverage: instances fresh vs stale vs "
         "never-run on this machine",
         "python -m repro store status --coverage"),
    ],
    "report": [
        ("render report/index.html + report.md for one run",
         "python -m repro report 20260731T120000-42"),
        ("cross-run trend report over everything in history.jsonl",
         "python -m repro report history --results-dir results"),
        ("wider drift window, custom output directory",
         "python -m repro report 20260731T120000-42 --output /tmp/report "
         "--window 10"),
        ("live dashboard over the result store: trend sparklines, drift "
         "alerts, and JSON query endpoints next to the static report",
         "python -m repro report history --serve --port 8000"),
    ],
}


def epilog(command: str) -> str:
    """RawDescriptionHelpFormatter-ready examples block for ``command``."""
    lines = ["examples:"]
    for what, cmd in EXAMPLES.get(command, []):
        lines.append(f"  # {what}")
        lines.append(f"  $ {cmd}")
        lines.append("")
    return "\n".join(lines).rstrip()
