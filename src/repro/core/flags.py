"""Command-line flag registration — the clara::Opts analogue (paper §III-G).

Scopes declare new flags at import/registration time; the core binary parses
them all in one pass.  Mirrors SCOPE's two-phase startup:

    register flags  →  (pre-parse hooks)  →  parse  →  (post-parse hooks)  →  run

Flags are namespaced per scope for collision freedom, but short names are
allowed when unique (matching clara's permissiveness).
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional


@dataclass
class FlagSpec:
    name: str                      # e.g. "example/seconds" or "min_time"
    help: str
    default: Any = None
    type: Callable[[str], Any] = str
    choices: Optional[List[Any]] = None
    is_bool: bool = False
    owner: str = "core"            # which scope declared it


class FlagRegistry:
    """Holds declared flags and parsed values."""

    def __init__(self) -> None:
        self._specs: Dict[str, FlagSpec] = {}
        self._values: Dict[str, Any] = {}
        self._parsed = False

    # -- declaration ------------------------------------------------------
    def declare(
        self,
        name: str,
        help: str = "",
        default: Any = None,
        type: Callable[[str], Any] = str,
        choices: Optional[List[Any]] = None,
        is_bool: bool = False,
        owner: str = "core",
    ) -> None:
        if name in self._specs:
            raise ValueError(f"flag {name!r} already declared by "
                             f"{self._specs[name].owner!r}")
        self._specs[name] = FlagSpec(name, help, default, type, choices,
                                     is_bool, owner)
        self._values[name] = default

    # -- parsing ----------------------------------------------------------
    def build_parser(self, parser: Optional[argparse.ArgumentParser] = None
                     ) -> argparse.ArgumentParser:
        parser = parser or argparse.ArgumentParser(prog="scope")
        for spec in self._specs.values():
            arg = "--" + spec.name.replace("/", ".")
            kwargs: Dict[str, Any] = dict(help=f"[{spec.owner}] {spec.help}",
                                          dest=spec.name, default=spec.default)
            if spec.is_bool:
                kwargs["action"] = "store_true"
                if spec.default:
                    kwargs["action"] = "store_false"
            else:
                kwargs["type"] = spec.type
                if spec.choices:
                    kwargs["choices"] = spec.choices
            parser.add_argument(arg, **kwargs)
        return parser

    def parse(self, argv: Optional[List[str]] = None,
              parser: Optional[argparse.ArgumentParser] = None,
              known_only: bool = True) -> argparse.Namespace:
        parser = self.build_parser(parser)
        if known_only:
            ns, _ = parser.parse_known_args(argv)
        else:
            ns = parser.parse_args(argv)
        for name in self._specs:
            self._values[name] = getattr(ns, name)
        self._parsed = True
        return ns

    # -- access -----------------------------------------------------------
    def get(self, name: str, default: Any = None) -> Any:
        if name in self._values:
            return self._values[name]
        return default

    def set(self, name: str, value: Any) -> None:
        self._values[name] = value

    def declared(self) -> List[FlagSpec]:
        return list(self._specs.values())

    def reset(self) -> None:
        self._specs.clear()
        self._values.clear()
        self._parsed = False


FLAGS = FlagRegistry()

# Core flags (the SCOPE binary's own options).
FLAGS.declare("benchmark_filter", help="regex selecting benchmarks to run",
              default=".*")
FLAGS.declare("benchmark_min_time", help="min seconds per benchmark timing",
              default=0.05, type=float)
FLAGS.declare("benchmark_repetitions", help="timing repetitions",
              default=1, type=int)
FLAGS.declare("benchmark_out", help="output JSON path", default=None)
FLAGS.declare("benchmark_list_tests", help="list benchmarks and exit",
              is_bool=True, default=False)
FLAGS.declare("log_level", help="log level", default="INFO")
