"""Benchmark, State & typed parameter spaces (paper §III-E).

SCOPE provides "the entire Google Benchmark library ... to configure and
register the benchmark code".  This module reimplements the parts of that
library's semantics that SCOPE's benchmarks rely on, in Python, and then
goes where Google Benchmark cannot: benchmarks here sweep **named, typed
axes**, not tuples of ints.

  * ``ParamSpace`` — named axes of JSON-able values (ints, floats,
    strings, bools) composed by product / zip / explicit cases, crossed
    with ``*``, concatenated with ``+``, and pruned by constraint
    predicates (``.where``).  One registered family covers every
    dtype/backend/layout variant instead of a hand-copied clone per
    variant.
  * ``Params`` — one point of a space, handed to benchmark bodies as
    ``state.params`` (``state.params.dtype``); ``state.range(i)`` stays
    as a compat shim over the int-valued axes.
  * ``State`` — the iteration object handed to a benchmark body.
    Supports the ``while state.keep_running():`` / ``for _ in state:``
    protocols, manual timing pause/resume, counters, bytes/items
    rates, ``skip_with_error``, the fixture context
    (``state.fixture``), and **sync deliverables**
    (``state.deliver(out)``): the body hands its outputs to the state
    so the measurement layer (repro.core.measure) can fence async
    dispatch *before the clock stops* — a body no longer blocks the
    device every iteration just to be measurable.  Per-*sample*
    measurements (one request's latency, one step's queue depth) flow
    through ``state.observe(sample)`` to meters implementing the
    observe channel, and ``state.now()`` is the sanctioned timestamp
    source for bodies that pace open-loop load.
  * ``Benchmark`` — a registered family: a body plus either a typed
    ``ParamSpace`` or a legacy int-tuple sweep (``args`` / ``ranges``,
    mirroring GB's ``->Args()``/``->Ranges()``), an optional *fixture*
    (``setup(params) -> ctx`` runs untimed before calibration, so
    array allocation and ``jax.jit`` construction leave the timed
    region), a time unit, and per-benchmark overrides.

Instance naming: typed families render every axis as ``name:value``
(``family/dtype:bf16/n:256``); legacy int-tuple families keep the exact
Google-Benchmark names they always had (``family/256`` or named via
``set_arg_names``), so plan IDs, baselines and history round-trip
byte-identically across the redesign.

Duplicate arg-sets / instances are rejected at registration time (they
would otherwise collide later as plan-ID duplicates), and ``set_unit``
raises ``ValueError`` on an unknown unit instead of ``assert`` (which
``python -O`` strips).
"""
from __future__ import annotations

import inspect
import itertools
import time
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

TIME_UNITS = {"ns": 1e9, "us": 1e6, "ms": 1e3, "s": 1.0}

#: Axis values must be JSON-able scalars — they appear in instance names,
#: plan metadata and manifests verbatim.
_SCALAR_TYPES = (bool, int, float, str)


class SkipError(Exception):
    """Raised internally when a benchmark calls skip_with_error."""


def format_value(v: Any) -> str:
    """Canonical string form of an axis value, as used in instance names
    and matched by ``--param key=value`` (bools are JSON-style)."""
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


class Params(Mapping):
    """One point of a parameter space: an ordered, read-only mapping of
    axis name → value with attribute access (``params.dtype``)."""

    __slots__ = ("_values",)

    def __init__(self, values: Optional[Dict[str, Any]] = None):
        object.__setattr__(self, "_values", dict(values or {}))

    # -- mapping protocol ---------------------------------------------
    def __getitem__(self, key: str) -> Any:
        return self._values[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._values)

    def __len__(self) -> int:
        return len(self._values)

    # -- attribute access ---------------------------------------------
    def __getattr__(self, name: str) -> Any:
        if name.startswith("_"):
            raise AttributeError(name)
        try:
            return self._values[name]
        except KeyError:
            raise AttributeError(
                f"no parameter axis {name!r} (have {list(self._values)})"
            ) from None

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("Params is read-only")

    # -- identity -----------------------------------------------------
    def canonical(self) -> str:
        """Canonical JSON of this point (sorted keys) — the stable,
        order-independent identity used for duplicate detection and
        recorded in plan metadata."""
        import json
        return json.dumps(self._values, sort_keys=True,
                          separators=(",", ":"))

    def int_values(self) -> Tuple[int, ...]:
        """The int-valued axes in axis order — what ``state.range(i)``
        indexes (the compat shim; bools are not ranges)."""
        return tuple(v for v in self._values.values()
                     if isinstance(v, int) and not isinstance(v, bool))

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v!r}" for k, v in self._values.items())
        return f"Params({inner})"


def _check_scalar(axis: str, v: Any) -> None:
    if not isinstance(v, _SCALAR_TYPES):
        raise TypeError(f"axis {axis!r}: value {v!r} is not a JSON-able "
                        f"scalar (int, float, str, bool)")


class ParamSpace:
    """Named, typed axes expanded into benchmark instances.

    Build one with :meth:`product`, :meth:`zip` or :meth:`cases`, then
    compose: ``*`` crosses two spaces with disjoint axes, ``+``
    concatenates two case lists, and :meth:`where` prunes by a
    constraint predicate::

        space = (ParamSpace.product(backend=["xla", "pallas"],
                                    dtype=["f32", "bf16"],
                                    n=[256, 512, 1024])
                 .where(lambda p: p.backend == "xla" or p.n <= 512))

    Duplicate points are rejected at construction time — they would
    produce identical instance names and collide later as plan-ID
    duplicates.
    """

    def __init__(self, points: Iterable[Dict[str, Any]]):
        self._points: List[Dict[str, Any]] = []
        seen: Dict[str, Dict[str, Any]] = {}
        for p in points:
            if not isinstance(p, dict) or not p:
                raise TypeError(f"each point must be a non-empty mapping "
                                f"(got {p!r})")
            for k, v in p.items():
                _check_scalar(k, v)
            key = Params(p).canonical()
            if key in seen:
                raise ValueError(f"duplicate parameter point {p!r}")
            seen[key] = p
            self._points.append(dict(p))

    # -- constructors ---------------------------------------------------
    @classmethod
    def product(cls, **axes: Sequence[Any]) -> "ParamSpace":
        """Cartesian product of named axes, in keyword order."""
        if not axes:
            return cls([])
        names = list(axes)
        return cls(dict(zip(names, combo))
                   for combo in itertools.product(*axes.values()))

    @classmethod
    def zip(cls, **axes: Sequence[Any]) -> "ParamSpace":
        """Equal-length axes zipped point-by-point (no cross product)."""
        lengths = {k: len(list(v)) for k, v in axes.items()}
        if len(set(lengths.values())) > 1:
            raise ValueError(f"zip axes must have equal lengths: {lengths}")
        names = list(axes)
        cols = [list(axes[n]) for n in names]
        return cls(dict(zip(names, row)) for row in zip(*cols))

    @classmethod
    def cases(cls, *points: Dict[str, Any]) -> "ParamSpace":
        """Explicit list of points (each a dict of axis → value)."""
        return cls(points)

    # -- composition ------------------------------------------------
    def where(self, pred: Callable[[Params], bool]) -> "ParamSpace":
        """Keep only the points the constraint predicate accepts."""
        return ParamSpace(p for p in self._points if pred(Params(p)))

    def __mul__(self, other: "ParamSpace") -> "ParamSpace":
        """Cross product of two spaces with disjoint axes."""
        overlap = set().union(*self._points or [{}]) & \
            set().union(*other._points or [{}])
        if overlap:
            raise ValueError(f"cannot cross spaces sharing axes {overlap}")
        return ParamSpace({**a, **b} for a in self._points
                          for b in other._points)

    def __add__(self, other: "ParamSpace") -> "ParamSpace":
        """Concatenate the case lists (duplicates still rejected)."""
        return ParamSpace(list(self._points) + list(other._points))

    # -- access -----------------------------------------------------
    def points(self) -> List[Params]:
        return [Params(p) for p in self._points]

    def axes(self) -> List[str]:
        """Axis names in first-seen order across all points."""
        out: List[str] = []
        for p in self._points:
            for k in p:
                if k not in out:
                    out.append(k)
        return out

    def __len__(self) -> int:
        return len(self._points)

    def __iter__(self) -> Iterator[Params]:
        return iter(self.points())


def parse_param_filter(pairs: Sequence[str]
                       ) -> Optional[Dict[str, List[str]]]:
    """``--param KEY=VALUE`` occurrences → ``{key: [values]}`` (None
    when empty).  Raises ``ValueError`` on a pair without ``=``."""
    out: Dict[str, List[str]] = {}
    for pair in pairs:
        if "=" not in pair:
            raise ValueError(f"--param expects KEY=VALUE, got {pair!r}")
        key, value = pair.split("=", 1)
        out.setdefault(key, []).append(value)
    return out or None


def name_params(name: str) -> Dict[str, str]:
    """Parse the ``axis:value`` components back out of an instance name
    (the inverse of typed naming, for documents where only names
    survive — baselines, history records)."""
    out: Dict[str, str] = {}
    for part in name.split("/")[1:]:
        if ":" in part:
            k, v = part.split(":", 1)
            out[k] = v
    return out


def match_params(params: Mapping, param_filter:
                 Optional[Dict[str, Sequence[str]]]) -> bool:
    """Does an instance's ``Params`` satisfy a ``--param`` filter?

    ``param_filter`` maps axis name → accepted *string* values (as typed
    on the command line); values are compared through
    :func:`format_value`, so ``--param n=256`` matches the int axis
    value ``256``.  Multiple values for one key OR together; distinct
    keys AND together.  An instance lacking a filtered axis never
    matches.
    """
    if not param_filter:
        return True
    for key, accepted in param_filter.items():
        if key not in params:
            return False
        if format_value(params[key]) not in accepted:
            return False
    return True


class State:
    """Iteration state for one benchmark run (one point of the space)."""

    def __init__(self, ranges: Sequence[int] = (), max_iterations: int = 1,
                 params: Optional[Params] = None, fixture: Any = None):
        self.params: Params = params if params is not None else Params()
        self._ranges: Tuple[int, ...] = (tuple(ranges) if ranges
                                         else self.params.int_values())
        self.fixture = fixture
        self.max_iterations = max_iterations
        self.iterations = 0
        self.counters: Dict[str, float] = {}
        self.bytes_processed = 0
        self.items_processed = 0
        self.label = ""
        self.error_occurred = False
        self.error_message = ""
        self.skipped = False
        self.skip_message = ""
        # sync deliverables: the batch's outputs, declared by the body
        # (state.deliver(out)); the measurement layer fences on them
        self.deliverables: Any = None
        # fence hook installed by the wall-clock meter: runs before the
        # stop timestamp is captured, so async dispatch is inside the
        # timed window (repro.core.measure.WallClockMeter)
        self._sync: Optional[Callable[["State"], Any]] = None
        # per-sample observer installed by the meter stack: state.observe
        # routes per-request samples (TTFT, latency, queue depth) to the
        # meters' observe channel (repro.core.measure.Meter.observe)
        self._observer: Optional[Callable[["State", Mapping], None]] = None
        # manual timing
        self._timing = False
        self._t_start = 0.0
        self._elapsed = 0.0
        self._cpu_start = 0.0
        self._cpu_elapsed = 0.0
        self._paused_elapsed = 0.0

    # -- GB arg access ------------------------------------------------
    def range(self, i: int = 0) -> int:
        """Compat shim: the i-th *int-valued* axis of ``state.params``
        (exactly the old tuple position for legacy int sweeps)."""
        return self._ranges[i]

    @property
    def ranges(self) -> Tuple[int, ...]:
        return self._ranges

    # -- iteration protocol --------------------------------------------
    def keep_running(self) -> bool:
        if self.error_occurred or self.skipped:
            return False
        if self.iterations == 0:
            self._start_timer()
        if self.iterations >= self.max_iterations:
            self._stop_timer()
            return False
        self.iterations += 1
        return True

    def __iter__(self):
        while self.keep_running():
            yield self.iterations

    # -- timing ----------------------------------------------------------
    def _start_timer(self) -> None:
        self._timing = True
        self._t_start = time.perf_counter()
        self._cpu_start = time.process_time()

    def _stop_timer(self) -> None:
        if self._timing:
            # fence BEFORE capturing the stop timestamp: async dispatch
            # (JAX enqueues work and returns) must complete inside the
            # timed window, or the clock measures enqueue cost
            if self._sync is not None:
                self._sync(self)
            self._elapsed += time.perf_counter() - self._t_start
            self._cpu_elapsed += time.process_time() - self._cpu_start
            self._timing = False

    def pause_timing(self) -> None:
        """GB PauseTiming(): exclude a section from the measured time."""
        self._stop_timer()

    def resume_timing(self) -> None:
        self._start_timer()

    def set_iteration_time(self, seconds: float) -> None:
        """GB SetIterationTime() for manual-time benchmarks."""
        self._paused_elapsed += seconds

    @property
    def elapsed(self) -> float:
        return self._elapsed

    @property
    def cpu_elapsed(self) -> float:
        """Process CPU seconds over the same window as :attr:`elapsed`."""
        return self._cpu_elapsed

    @property
    def manual_elapsed(self) -> float:
        return self._paused_elapsed

    @staticmethod
    def now() -> float:
        """Sanctioned monotonic timestamp for bodies that *schedule* work.

        Benchmark bodies must not read host clocks to time themselves
        (the meter stack owns timing; repro lint SCOPE105 enforces it) —
        but an open-loop load generator legitimately needs the current
        time to pace arrivals and stamp per-request samples.  ``state
        .now()`` is that sanctioned source: same epoch as the timer
        (``time.perf_counter``), and its readings are only meaningful
        relative to each other.
        """
        return time.perf_counter()

    # -- results ----------------------------------------------------------
    def deliver(self, value: Any) -> Any:
        """Declare the batch's output as the sync deliverable.

        Call inside the timed loop with whatever the body computes
        (``state.deliver(fn(x))``); the default sync fence blocks on the
        *last* delivered value before the clock stops, so the whole
        pipelined batch — not just its enqueue — is measured.  Returns
        ``value`` so it can wrap an expression in place.
        """
        self.deliverables = value
        return value

    def observe(self, sample: Mapping) -> Mapping:
        """Deliver one per-*sample* measurement to the meter stack.

        ``begin``/``end`` bracket a whole batch; some measurements are
        per-event inside it — one serving request's TTFT and end-to-end
        latency, one step's queue depth.  The body hands each event to
        ``state.observe({"latency_s": ..., ...})`` and meters that
        implement the observe channel (repro.core.measure.Meter.observe,
        e.g. ``--meters latency``) aggregate them into counters.  With
        no observing meter installed the sample is dropped — bodies
        never need to know which meters are measuring them.  Returns
        ``sample`` so it can wrap an expression in place.
        """
        if self._observer is not None:
            self._observer(self, sample)
        return sample

    def set_bytes_processed(self, n: int) -> None:
        self.bytes_processed = n

    def set_items_processed(self, n: int) -> None:
        self.items_processed = n

    def set_label(self, label: str) -> None:
        self.label = label

    def skip_with_error(self, msg: str) -> None:
        self.error_occurred = True
        self.error_message = msg

    def skip_with_message(self, msg: str) -> None:
        self.skipped = True
        self.skip_message = msg


BenchmarkFn = Callable[[State], None]
FixtureFn = Callable[[Params], Any]


def _capture_source(fn: Any) -> Tuple[Optional[str], str, int]:
    """Best-effort ``(source, file, line)`` for a registered callable.

    Captured eagerly at registration so the static-analysis pass
    (repro.core.lint) still sees the text when the defining module is
    later unimportable or the function was built dynamically.
    """
    try:
        source = inspect.getsource(fn)
    except (OSError, TypeError):
        return None, "", 0
    try:
        file = inspect.getsourcefile(fn) or ""
        line = inspect.getsourcelines(fn)[1]
    except (OSError, TypeError):
        file, line = "", 0
    return source, file, line


@dataclass(frozen=True)
class Tunable:
    """A family's tunable-kernel declaration (``python -m repro tune``).

    ``kernel`` names the :mod:`repro.kernels.tuning` artifact the search
    writes; ``space`` is the knob space searched (axes must be that
    kernel's knobs); ``instance`` is a parameter filter (axis →
    formatted value) selecting which point of the family's sweep each
    trial drives.  The core stays kernel-agnostic — knob validity is
    checked by the tune CLI against the tuning registry.
    """

    kernel: str
    space: ParamSpace
    instance: Tuple[Tuple[str, str], ...] = ()

    def instance_filter(self) -> Optional[Dict[str, List[str]]]:
        """The declaration's filter in ``--param`` shape (None if empty)."""
        return {k: [v] for k, v in self.instance} or None


@dataclass
class Benchmark:
    """A registered benchmark family (body + parameter space + metadata).

    The sweep is either a typed :class:`ParamSpace` (``param_space``) or
    a legacy int-tuple sweep built with the GB-style fluent builders —
    never both.  Legacy sweeps keep their exact historical instance
    names; typed sweeps render every axis as ``name:value``.
    """

    name: str
    fn: BenchmarkFn
    scope: str = "core"
    arg_sets: List[Tuple[int, ...]] = field(default_factory=list)
    arg_names: List[str] = field(default_factory=list)
    space: Optional[ParamSpace] = None
    fixture: Optional[FixtureFn] = None
    unit: str = "us"
    min_time: Optional[float] = None       # per-benchmark override
    repetitions: Optional[int] = None
    iterations: Optional[int] = None       # fixed iteration count (no adaptation)
    use_manual_time: bool = False
    # per-family measurement overrides (repro.core.measure): a sync(ctx)
    # fence for the wall meter, and a meter-set override (names or
    # Meter instances) taking precedence over RunOptions.meters
    sync_fn: Optional[Callable[[Any], Any]] = None
    meters: Optional[List[Any]] = None
    # tunable-kernel declaration (python -m repro tune): which
    # repro.kernels.tuning artifact this family's measurements feed,
    # the knob space to search, and the instance point to drive
    tunable: Optional[Tunable] = None
    labels: Dict[str, str] = field(default_factory=dict)
    doc: str = ""
    # source captured at registration time for the static-analysis pass
    # (repro.core.lint) — None when inspect.getsource cannot see it
    # (lambdas, REPL definitions); the linter then degrades to SCOPE000.
    source: Optional[str] = None
    source_file: str = ""
    source_line: int = 0
    fixture_source: Optional[str] = None

    # -- typed sweep builders -------------------------------------------
    def param_space(self, space: Optional[ParamSpace] = None,
                    **axes: Sequence[Any]) -> "Benchmark":
        """Attach a typed parameter space (or build a product from
        keyword axes): ``b.param_space(dtype=["f32", "bf16"], n=[256])``."""
        if self.arg_sets:
            raise ValueError(
                f"benchmark {self.name!r} already has int-tuple arg-sets; "
                "a family is typed or legacy, not both")
        if space is not None and axes:
            raise ValueError("pass a ParamSpace or keyword axes, not both")
        self.space = space if space is not None \
            else ParamSpace.product(**axes)
        return self

    def set_fixture(self, fn: FixtureFn) -> "Benchmark":
        """``setup(params) -> ctx`` runs once per instance, untimed,
        before calibration; the context is handed to the body as
        ``state.fixture``."""
        self.fixture = fn
        self.fixture_source = _capture_source(fn)[0]
        return self

    def set_sync(self, fn: Callable[[Any], Any]) -> "Benchmark":
        """Per-family device-sync fence, run by the wall-clock meter
        *before the clock stops* (repro.core.measure).

        ``fn(state)`` receives the batch state (``state.deliverables``,
        ``state.fixture``, ``state.params``).  Default when unset:
        ``jax.block_until_ready`` over the delivered outputs (falling
        back to the fixture context).  Pass a no-op (``lambda ctx:
        None``) to declare a host-synchronous family that needs no
        fence.
        """
        self.sync_fn = fn
        return self

    def set_meters(self, *meters: Any) -> "Benchmark":
        """Per-family meter-set override: names from
        ``repro.core.measure.METERS`` and/or Meter instances.  Takes
        precedence over the run-level ``--meters`` selection; the wall
        and CPU meters are always included (the time sources).  Name
        typos fail here, at registration — not as per-instance error
        records at run time."""
        from .measure import validate_meter_name
        for m in meters:
            if isinstance(m, str):
                validate_meter_name(m)
        self.meters = list(meters)
        return self

    def set_tunable(self, kernel: str, space: Optional[ParamSpace] = None,
                    instance: Optional[Dict[str, Any]] = None,
                    **axes: Sequence[Any]) -> "Benchmark":
        """Declare the tunable kernel this family measures::

            matmul.set_tunable("matmul", bm=[128, 256], bn=[128, 256],
                               bk=[128, 256],
                               instance={"backend": "pallas"})

        ``python -m repro tune <family>`` searches the knob space, runs
        this family's ``instance`` point per trial, and ships the winner
        as the kernel's tuned.json default."""
        if space is not None and axes:
            raise ValueError("pass a ParamSpace or keyword axes, not both")
        space = space if space is not None else ParamSpace.product(**axes)
        if not len(space):
            raise ValueError(
                f"benchmark {self.name!r}: tunable knob space is empty")
        inst = tuple(sorted((k, format_value(v))
                            for k, v in (instance or {}).items()))
        self.tunable = Tunable(kernel=kernel, space=space, instance=inst)
        return self

    # -- GB-style fluent sweep builders -----------------------------------
    def _append_arg_set(self, values: Tuple[int, ...]) -> None:
        if self.space is not None:
            raise ValueError(
                f"benchmark {self.name!r} already has a ParamSpace; "
                "a family is typed or legacy, not both")
        if values in self.arg_sets:
            raise ValueError(
                f"benchmark {self.name!r}: duplicate arg-set {values!r} "
                f"(instance {self.instance_name(values)!r} would collide)")
        self.arg_sets.append(values)

    def args(self, values: Sequence[int]) -> "Benchmark":
        self._append_arg_set(tuple(values))
        return self

    def args_product(self, lists: Sequence[Sequence[int]]) -> "Benchmark":
        """GB ArgsProduct: cartesian product of per-position value lists."""
        for combo in itertools.product(*lists):
            self._append_arg_set(tuple(combo))
        return self

    def range_multiplier_args(self, lo: int, hi: int, mult: int = 2
                              ) -> "Benchmark":
        """GB Range(lo, hi): geometric sweep of a single argument."""
        v = lo
        while v <= hi:
            self._append_arg_set((v,))
            v *= mult
        return self

    def ranges(self, pairs: Sequence[Tuple[int, int]], mult: int = 2
               ) -> "Benchmark":
        """GB Ranges: cartesian product of geometric sweeps."""
        axes: List[List[int]] = []
        for lo, hi in pairs:
            ax, v = [], lo
            while v <= hi:
                ax.append(v)
                v *= mult
            axes.append(ax)
        for combo in itertools.product(*axes):
            self._append_arg_set(tuple(combo))
        return self

    def set_arg_names(self, names: Sequence[str]) -> "Benchmark":
        self.arg_names = list(names)
        return self

    def set_unit(self, unit: str) -> "Benchmark":
        if unit not in TIME_UNITS:
            raise ValueError(f"unknown time unit {unit!r} (expected one "
                             f"of: {', '.join(TIME_UNITS)})")
        self.unit = unit
        return self

    def set_min_time(self, seconds: float) -> "Benchmark":
        self.min_time = seconds
        return self

    def set_iterations(self, n: int) -> "Benchmark":
        self.iterations = n
        return self

    def manual_time(self) -> "Benchmark":
        self.use_manual_time = True
        return self

    def set_label(self, key: str, value: str) -> "Benchmark":
        self.labels[key] = value
        return self

    # -- naming -------------------------------------------------------
    def _legacy_params(self, arg_set: Tuple[int, ...]) -> Params:
        """Params view of a legacy int arg-set: named axes when
        ``set_arg_names`` matches, positional ``arg<i>`` keys otherwise."""
        if self.arg_names and len(self.arg_names) == len(arg_set):
            return Params(dict(zip(self.arg_names, arg_set)))
        return Params({f"arg{i}": v for i, v in enumerate(arg_set)})

    def instance_name(self, point) -> str:
        """Display name of one instance.

        Typed families: ``family/axis:value/...`` for every axis.
        Legacy families (``point`` may also be the raw int tuple):
        GB-style ``family/arg0/arg1`` or named args — byte-identical to
        the pre-ParamSpace naming.
        """
        if self.space is not None:
            parts = [f"{k}:{format_value(v)}" for k, v in point.items()]
            return self.name + "/" + "/".join(parts) if parts else self.name
        arg_set = tuple(point.values()) if isinstance(point, Mapping) \
            else tuple(point)
        if not arg_set:
            return self.name
        if self.arg_names and len(self.arg_names) == len(arg_set):
            parts = [f"{n}:{v}" for n, v in zip(self.arg_names, arg_set)]
        else:
            parts = [str(v) for v in arg_set]
        return self.name + "/" + "/".join(parts)

    def instances(self) -> List[Tuple[str, Params]]:
        """Every (display name, Params) instance of this family."""
        if self.space is not None:
            return [(self.instance_name(p), p) for p in self.space.points()]
        sets = self.arg_sets or [()]
        return [(self.instance_name(s), self._legacy_params(s))
                for s in sets]
