"""Benchmark & State — the Google Benchmark library analogue (paper §III-E).

SCOPE provides "the entire Google Benchmark library ... to configure and
register the benchmark code".  This module reimplements the parts of that
library's semantics that SCOPE's benchmarks rely on, in Python:

  * ``State`` — the iteration object handed to a benchmark body.  Supports
    the ``while state.keep_running():`` / ``for _ in state:`` protocols,
    manual timing pause/resume, counters, bytes/items-processed rates, and
    ``skip_with_error``.
  * ``Benchmark`` — a registered benchmark family: a body plus an argument
    sweep (``args`` / ``ranges``, mirroring GB's ``->Args()``/``->Ranges()``),
    a time unit, and optional per-benchmark min-time/repetitions overrides.

The runner (runner.py) drives State with adaptive iteration counts exactly
like Google Benchmark: batches grow geometrically until the measured time
exceeds ``min_time``.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

TIME_UNITS = {"ns": 1e9, "us": 1e6, "ms": 1e3, "s": 1.0}


class SkipError(Exception):
    """Raised internally when a benchmark calls skip_with_error."""


class State:
    """Iteration state for one benchmark run (one point in the arg sweep)."""

    def __init__(self, ranges: Sequence[int] = (), max_iterations: int = 1):
        self._ranges: Tuple[int, ...] = tuple(ranges)
        self.max_iterations = max_iterations
        self.iterations = 0
        self.counters: Dict[str, float] = {}
        self.bytes_processed = 0
        self.items_processed = 0
        self.label = ""
        self.error_occurred = False
        self.error_message = ""
        self.skipped = False
        self.skip_message = ""
        # manual timing
        self._timing = False
        self._t_start = 0.0
        self._elapsed = 0.0
        self._paused_elapsed = 0.0

    # -- GB arg access ------------------------------------------------
    def range(self, i: int = 0) -> int:
        return self._ranges[i]

    @property
    def ranges(self) -> Tuple[int, ...]:
        return self._ranges

    # -- iteration protocol --------------------------------------------
    def keep_running(self) -> bool:
        if self.error_occurred or self.skipped:
            return False
        if self.iterations == 0:
            self._start_timer()
        if self.iterations >= self.max_iterations:
            self._stop_timer()
            return False
        self.iterations += 1
        return True

    def __iter__(self):
        while self.keep_running():
            yield self.iterations

    # -- timing ----------------------------------------------------------
    def _start_timer(self) -> None:
        self._timing = True
        self._t_start = time.perf_counter()

    def _stop_timer(self) -> None:
        if self._timing:
            self._elapsed += time.perf_counter() - self._t_start
            self._timing = False

    def pause_timing(self) -> None:
        """GB PauseTiming(): exclude a section from the measured time."""
        self._stop_timer()

    def resume_timing(self) -> None:
        self._start_timer()

    def set_iteration_time(self, seconds: float) -> None:
        """GB SetIterationTime() for manual-time benchmarks."""
        self._paused_elapsed += seconds

    @property
    def elapsed(self) -> float:
        return self._elapsed

    @property
    def manual_elapsed(self) -> float:
        return self._paused_elapsed

    # -- results ----------------------------------------------------------
    def set_bytes_processed(self, n: int) -> None:
        self.bytes_processed = n

    def set_items_processed(self, n: int) -> None:
        self.items_processed = n

    def set_label(self, label: str) -> None:
        self.label = label

    def skip_with_error(self, msg: str) -> None:
        self.error_occurred = True
        self.error_message = msg

    def skip_with_message(self, msg: str) -> None:
        self.skipped = True
        self.skip_message = msg


BenchmarkFn = Callable[[State], None]


@dataclass
class Benchmark:
    """A registered benchmark family (body + argument sweep + metadata)."""

    name: str
    fn: BenchmarkFn
    scope: str = "core"
    arg_sets: List[Tuple[int, ...]] = field(default_factory=list)
    arg_names: List[str] = field(default_factory=list)
    unit: str = "us"
    min_time: Optional[float] = None       # per-benchmark override
    repetitions: Optional[int] = None
    iterations: Optional[int] = None       # fixed iteration count (no adaptation)
    use_manual_time: bool = False
    labels: Dict[str, str] = field(default_factory=dict)
    doc: str = ""

    # -- GB-style fluent sweep builders -----------------------------------
    def args(self, values: Sequence[int]) -> "Benchmark":
        self.arg_sets.append(tuple(values))
        return self

    def args_product(self, lists: Sequence[Sequence[int]]) -> "Benchmark":
        """GB ArgsProduct: cartesian product of per-position value lists."""
        for combo in itertools.product(*lists):
            self.arg_sets.append(tuple(combo))
        return self

    def range_multiplier_args(self, lo: int, hi: int, mult: int = 2
                              ) -> "Benchmark":
        """GB Range(lo, hi): geometric sweep of a single argument."""
        v = lo
        while v <= hi:
            self.arg_sets.append((v,))
            v *= mult
        return self

    def ranges(self, pairs: Sequence[Tuple[int, int]], mult: int = 2
               ) -> "Benchmark":
        """GB Ranges: cartesian product of geometric sweeps."""
        axes: List[List[int]] = []
        for lo, hi in pairs:
            ax, v = [], lo
            while v <= hi:
                ax.append(v)
                v *= mult
            axes.append(ax)
        for combo in itertools.product(*axes):
            self.arg_sets.append(tuple(combo))
        return self

    def set_arg_names(self, names: Sequence[str]) -> "Benchmark":
        self.arg_names = list(names)
        return self

    def set_unit(self, unit: str) -> "Benchmark":
        assert unit in TIME_UNITS, unit
        self.unit = unit
        return self

    def set_min_time(self, seconds: float) -> "Benchmark":
        self.min_time = seconds
        return self

    def set_iterations(self, n: int) -> "Benchmark":
        self.iterations = n
        return self

    def manual_time(self) -> "Benchmark":
        self.use_manual_time = True
        return self

    def set_label(self, key: str, value: str) -> "Benchmark":
        self.labels[key] = value
        return self

    # -- naming -------------------------------------------------------
    def instance_name(self, arg_set: Tuple[int, ...]) -> str:
        """GB-style display name: ``family/arg0/arg1`` or named args."""
        if not arg_set:
            return self.name
        if self.arg_names and len(self.arg_names) == len(arg_set):
            parts = [f"{n}:{v}" for n, v in zip(self.arg_names, arg_set)]
        else:
            parts = [str(v) for v in arg_set]
        return self.name + "/" + "/".join(parts)

    def instances(self) -> List[Tuple[str, Tuple[int, ...]]]:
        sets = self.arg_sets or [()]
        return [(self.instance_name(s), s) for s in sets]
