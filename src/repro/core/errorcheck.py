"""Error-checking utilities — the CUDA-error-check analogue (paper §III-E).

The paper ships ``CUDA_CHECK``-style helpers because "most extant benchmarks
are CUDA benchmarks".  Our benchmarks are JAX programs; the failure modes
worth guarding uniformly are numerical (NaN/Inf escaping a step), sharding
(outputs losing their intended layout), and compilation (lowering errors that
should fail a benchmark rather than crash the binary).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import numpy as np


class ScopeError(RuntimeError):
    """Uniform error type raised by the check helpers."""


def check_finite(tree: Any, where: str = "") -> Any:
    """Raise ScopeError if any leaf of ``tree`` contains NaN/Inf.

    Call on *concrete* values (post-``block_until_ready``), not traced ones.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f" and not np.all(np.isfinite(arr)):
            raise ScopeError(
                f"non-finite value in leaf {i}" + (f" at {where}" if where else "")
            )
    return tree


def check_shape(x: Any, expected: tuple, where: str = "") -> Any:
    if tuple(x.shape) != tuple(expected):
        raise ScopeError(
            f"shape mismatch{' at ' + where if where else ''}: "
            f"got {tuple(x.shape)}, want {tuple(expected)}"
        )
    return x


def check_sharding(x: jax.Array, spec, where: str = "") -> jax.Array:
    """Assert a concrete array's sharding matches a PartitionSpec."""
    got = getattr(x.sharding, "spec", None)
    if got is not None and tuple(got) != tuple(spec):
        raise ScopeError(
            f"sharding mismatch{' at ' + where if where else ''}: "
            f"got {got}, want {spec}"
        )
    return x


def check_compiles(fn: Callable, *args, **kwargs):
    """Lower+compile ``fn`` AOT; convert XLA errors into ScopeError."""
    try:
        return jax.jit(fn).lower(*args, **kwargs).compile()
    except Exception as e:
        raise ScopeError(f"compilation failed: {e}") from e


def checked(fn: Callable) -> Callable:
    """Decorator: block on outputs and run check_finite on them."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        out = fn(*args, **kwargs)
        out = jax.block_until_ready(out)
        return check_finite(out, where=fn.__name__)

    return wrapper


def sync(x: Any) -> Any:
    """Device synchronization — the ``cudaDeviceSynchronize`` of this stack."""
    return jax.block_until_ready(x)
