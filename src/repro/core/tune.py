"""``python -m repro tune`` — search-driven kernel autotuning.

Closes the measure→optimize loop the continuous-benchmarking literature
asks for: a tunable family (``Benchmark.set_tunable``) names the
:mod:`repro.kernels.tuning` kernel it measures and the knob space to
search; this command explores that space with :mod:`repro.core.search`
(factorial screening → greedy hill-climb under a trial budget), running
the family's instance once per candidate config through
``runner.run_single_instance`` + the MeterStack, then

  * records every trial in ``<results-dir>/<run-id>/merged.json`` and
    appends them to ``history.jsonl`` tagged ``tune`` (trial names are
    ``tune/<kernel>/<knob:value>/...`` so scope trend plots never
    confuse them with benchmark records);
  * writes the winner to the kernel's ``tuned.json`` artifact, which
    every kernel wrapper loads as its default blocks;
  * renders a tune report (speedup vs the builtin-default baseline and
    the screening sensitivity table) via ``repro.scopeplot``.

Determinism: with a fixed ``--seed`` the candidate plan is a pure
function of the space and the measured scores; ``--costs`` reorders
candidate evaluation toward configs a prior tune run measured cheapest.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
from typing import Any, Dict, List, Mapping, Optional

from . import logging as scope_logging
from .benchmark import (Params, TIME_UNITS, format_value, match_params,
                        parse_param_filter)
from .cli_examples import epilog
from .flags import FLAGS
from .history import append_run, doc_counters
from .measure import parse_meters
from .plan import load_cost_hints
from .registry import REGISTRY
from .runner import RunOptions, run_single_instance, write_json
from .search import (STRATEGIES, SearchResult, TrialError, lower_is_better,
                     run_search)

log = scope_logging.get_logger("tune")

#: Tune trials measure cost-model counters by default — the Pareto
#: frontier wants ``flops_per_second`` next to ``real_time_s``.
DEFAULT_TUNE_METERS = ["wall", "cpu", "costmodel"]


def build_tune_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro tune",
                                 add_help=False, epilog=epilog("tune"),
                                 formatter_class=
                                 argparse.RawDescriptionHelpFormatter)
    ap.add_argument("family", nargs="?", default=None,
                    help="a tunable benchmark family (registered name, "
                         "e.g. mxu/matmul); see --list")
    ap.add_argument("--list", action="store_true",
                    help="list every family that declares a tunable "
                         "kernel space, then exit")
    ap.add_argument("--budget", type=int, default=16,
                    help="hard cap on measured configs (default 16); "
                         "cached repeats are free, the builtin-default "
                         "baseline is measured outside the budget when "
                         "it lies outside the space")
    ap.add_argument("--strategy", default="auto",
                    choices=list(STRATEGIES),
                    help="auto = factorial screening, then hill-climb "
                         "from the best screened configs (default)")
    ap.add_argument("--objective", default="real_time_s",
                    metavar="METRIC",
                    help="trial metric to optimize (default real_time_s; "
                         "minimized unless it ends in _per_second — "
                         "e.g. flops_per_second needs the costmodel "
                         "meter)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the hill-climb's neighbor ordering "
                         "(default 0; same seed ⇒ same trial plan)")
    ap.add_argument("--param", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="override/narrow the instance the trials drive "
                         "(merged over the family's tunable instance "
                         "filter)")
    ap.add_argument("--meters", default=None, metavar="LIST",
                    help="comma-separated meters per trial (default "
                         "wall,cpu,costmodel)")
    ap.add_argument("--costs", default=None, metavar="PATH",
                    help="prior run directory or GB-JSON document; "
                         "matching trial names steer the budget toward "
                         "cheap configs first")
    ap.add_argument("--results-dir", default="results",
                    help="trial records land under <dir>/<run-id>/ and "
                         "append to <dir>/history.jsonl tagged 'tune' "
                         "(default: results)")
    ap.add_argument("--run-id", default=None,
                    help="run directory name (default: timestamp)")
    ap.add_argument("--enable-scope", action="append", default=None,
                    help="enable ONLY these scopes (repeatable)")
    ap.add_argument("--disable-scope", action="append", default=[])
    ap.add_argument("--output", default=None, metavar="PATH",
                    help="write the tuned artifact here instead of "
                         "src/repro/kernels/<kernel>/tuned.json")
    ap.add_argument("--no-artifact", action="store_true",
                    help="search + record + report, but do not write "
                         "tuned.json")
    ap.add_argument("--no-report", action="store_true",
                    help="skip rendering the tune report")
    return ap


def _trial_metrics(doc: Dict[str, Any]) -> Dict[str, float]:
    """Mean metrics of one trial document; raises :class:`TrialError`
    when the instance errored (the trial still consumed budget)."""
    recs = doc.get("benchmarks", [])
    bad = [r for r in recs if r.get("error_occurred") or r.get("skipped")]
    if bad:
        raise TrialError(bad[0].get("error_message", "instance errored"))
    reals: List[float] = []
    cpus: List[float] = []
    for r in recs:
        if r.get("run_type") != "iteration":
            continue
        scale = TIME_UNITS.get(r.get("time_unit", "ns"), 1e9)
        if r.get("real_time") is not None:
            reals.append(r["real_time"] / scale)
        if r.get("cpu_time") is not None:
            cpus.append(r["cpu_time"] / scale)
    if not reals:
        raise TrialError("trial produced no iteration records")
    metrics = {"real_time_s": statistics.fmean(reals)}
    if cpus:
        metrics["cpu_time_s"] = statistics.fmean(cpus)
    for counters in doc_counters(doc).values():
        for k, v in counters.items():
            metrics.setdefault(k, v)
            # derive rates so flops_per_second is an objective/Pareto
            # axis even though meters record raw per-call counters
            if not k.endswith("_per_second") and metrics["real_time_s"] > 0:
                metrics.setdefault(f"{k}_per_second",
                                   v / metrics["real_time_s"])
    return metrics


def _rename_records(doc: Dict[str, Any], new_name: str) -> None:
    """Rebrand a trial doc's records as ``tune/...`` names (aggregate
    suffixes like ``_mean`` are preserved)."""
    for rec in doc.get("benchmarks", []):
        old = rec.get("run_name") or rec.get("name", "")
        name = rec.get("name", old)
        suffix = name[len(old):] if old and name.startswith(old) else ""
        rec["run_name"] = new_name
        rec["name"] = new_name + suffix


def _print_tunables() -> None:
    rows = [(b.name, b.tunable) for b in REGISTRY.all()
            if b.tunable is not None]
    if not rows:
        print("no registered family declares a tunable kernel "
              "(Benchmark.set_tunable)")
        return
    width = max(len(n) for n, _ in rows)
    for name, t in sorted(rows):
        inst = ",".join(f"{k}={v}" for k, v in t.instance) or "-"
        print(f"{name:<{width}}  kernel={t.kernel}  "
              f"space={'x'.join(t.space.axes())} ({len(t.space)} configs)  "
              f"instance={inst}")


def tune_main(argv: List[str],
              scope_modules: Optional[List[str]] = None) -> int:
    ap = build_tune_parser()
    if any(a in ("-h", "--help") for a in argv):
        print(ap.format_help())
        return 0
    ns, rest = ap.parse_known_args(argv)

    try:
        param_filter = parse_param_filter(ns.param)
    except ValueError as e:
        log.error("%s", e)
        return 2
    meters: List[Any] = list(DEFAULT_TUNE_METERS)
    if ns.meters:
        try:
            meters = parse_meters(ns.meters)
        except ValueError as e:
            log.error("%s", e)
            return 2

    from .main import _setup_scopes
    mgr, rc = _setup_scopes(scope_modules, ns.enable_scope,
                            ns.disable_scope, rest)
    if mgr is None:
        return rc
    mgr.register_all()

    if ns.list:
        _print_tunables()
        return 0
    if not ns.family:
        log.error("tune needs a family to search (or --list)")
        _print_tunables()
        return 2

    bench = next((b for b in REGISTRY.all() if b.name == ns.family), None)
    if bench is None:
        log.error("no benchmark family named %r", ns.family)
        _print_tunables()
        return 1
    if bench.tunable is None:
        log.error("family %r declares no tunable kernel space "
                  "(Benchmark.set_tunable)", ns.family)
        _print_tunables()
        return 1
    tun = bench.tunable

    from repro.kernels import tuning
    if tun.kernel not in tuning.KERNEL_KNOBS:
        log.error("family %r names unknown kernel %r (known: %s)",
                  ns.family, tun.kernel, ", ".join(tuning.KERNEL_KNOBS))
        return 1
    axes = tun.space.axes()
    bad = [a for a in axes if a not in tuning.KERNEL_KNOBS[tun.kernel]]
    if bad:
        log.error("family %r: axes %s are not %s knobs (knobs: %s)",
                  ns.family, ", ".join(bad), tun.kernel,
                  ", ".join(tuning.KERNEL_KNOBS[tun.kernel]))
        return 1

    # pick the instance the trials drive: the family's declared filter,
    # narrowed by --param
    filt: Dict[str, List[str]] = dict(tun.instance_filter() or {})
    for k, v in (param_filter or {}).items():
        filt[k] = v
    instance_name = None
    for name, params in bench.instances():
        if match_params(params, filt or None):
            instance_name = name
            break
    if instance_name is None:
        log.error("no instance of %r matches %s", ns.family,
                  {k: v[0] if len(v) == 1 else v for k, v in filt.items()})
        return 1

    from .orchestrate import default_run_id
    run_id = ns.run_id or default_run_id()
    opts = RunOptions(min_time=FLAGS.get("benchmark_min_time", 0.05),
                      repetitions=FLAGS.get("benchmark_repetitions", 1),
                      meters=meters)

    def trial_name(cfg: Mapping[str, Any]) -> str:
        return f"tune/{tun.kernel}/" + "/".join(
            f"{a}:{format_value(cfg[a])}" for a in axes if a in cfg)

    trial_docs: List[Dict[str, Any]] = []

    def measure(cfg: Mapping[str, Any]) -> Dict[str, float]:
        config = {k: int(v) for k, v in cfg.items()}
        name = trial_name(cfg)
        log.info("trial %s", name)
        with tuning.override(tun.kernel, config):
            doc = run_single_instance(
                [bench], instance_name, opts,
                context_extra={"run_id": run_id,
                               "tune": {"kernel": tun.kernel,
                                        "family": bench.name}})
        _rename_records(doc, name)
        trial_docs.append(doc)
        return _trial_metrics(doc)

    hint_fn = None
    if ns.costs:
        hints: Dict[str, float] = {}
        try:
            hints = load_cost_hints(ns.costs)
        except (OSError, json.JSONDecodeError) as e:
            log.warning("cost source %s unreadable (%s); searching "
                        "without hints", ns.costs, e)
        if hints:
            hint_fn = lambda p: hints.get(trial_name(p))  # noqa: E731

    # the builtin-default config anchors the before/after speedup.  In
    # space it joins the search (one budgeted, reusable trial); outside
    # it is measured separately, budget-exempt.
    base_cfg = {a: tuning.BUILTIN_DEFAULTS[tun.kernel][a] for a in axes}
    base_in_space = any(p.canonical() == Params(base_cfg).canonical()
                       for p in tun.space.points())
    baseline_info: Optional[Dict[str, Any]] = None
    if not base_in_space:
        try:
            baseline_info = {"params": dict(base_cfg),
                             "metrics": measure(base_cfg)}
        except TrialError as e:
            log.warning("baseline config %s failed: %s", base_cfg, e)
            baseline_info = {"params": dict(base_cfg), "error": str(e)}

    result: SearchResult = run_search(
        tun.space, measure, objective=ns.objective, strategy=ns.strategy,
        budget=ns.budget, seed=ns.seed, cost_hint=hint_fn,
        baseline=Params(base_cfg) if base_in_space else None)
    if result.baseline is not None and result.baseline.ok:
        baseline_info = {"params": dict(result.baseline.params),
                         "metrics": dict(result.baseline.metrics)}

    if result.best is None:
        log.error("no trial produced objective %r — check --objective "
                  "and --meters (trials recorded under %s)",
                  ns.objective, os.path.join(ns.results_dir, run_id))
        best_cfg = None
    else:
        best_cfg = {k: int(v) for k, v in result.best.params.items()}

    speedup = None
    if (result.best is not None and baseline_info
            and "metrics" in baseline_info
            and ns.objective in baseline_info["metrics"]
            and ns.objective in result.best.metrics):
        b = baseline_info["metrics"][ns.objective]
        w = result.best.metrics[ns.objective]
        if b > 0 and w > 0:
            speedup = b / w if lower_is_better(ns.objective) else w / b

    # ---- persist: merged trial doc + history (tagged) + summary ----
    run_dir = os.path.join(ns.results_dir, run_id)
    os.makedirs(run_dir, exist_ok=True)
    merged = {
        "context": trial_docs[0]["context"] if trial_docs else {},
        "benchmarks": [r for d in trial_docs for r in d["benchmarks"]],
    }
    merged_path = os.path.join(run_dir, "merged.json")
    write_json(merged, merged_path)
    appended = append_run(ns.results_dir, merged, run_id=run_id,
                          tag="tune")
    summary = {
        "family": bench.name, "instance": instance_name,
        "kernel": tun.kernel, "axes": axes, "run_id": run_id,
        "objective": ns.objective, "baseline": baseline_info,
        "best": None if result.best is None else {
            "params": best_cfg, "metrics": dict(result.best.metrics)},
        "speedup": speedup,
        "search": result.to_json(),
    }
    with open(os.path.join(run_dir, "tune.json"), "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")
    log.info("recorded %d trial(s) under %s (%d history record(s))",
             len(trial_docs), run_dir, len(appended))

    if result.best is None:
        return 1

    artifact_path = None
    if not ns.no_artifact:
        payload = {
            "kernel": tun.kernel, "config": best_cfg,
            "objective": ns.objective, "strategy": ns.strategy,
            "budget": ns.budget, "seed": ns.seed,
            "source": {"family": bench.name, "instance": instance_name,
                       "run_id": run_id},
        }
        artifact_path = tuning.write_tuned(tun.kernel, payload,
                                           path=ns.output)

    report_path = None
    if not ns.no_report:
        from repro.scopeplot.report import generate_tune_report
        try:
            report_path = generate_tune_report(run_dir)["html"]
        except Exception as e:  # noqa: BLE001 - a report must not lose the tune
            log.warning("tune report failed: %s", e)

    # ---- human summary ------------------------------------------------
    def _fmt(v: float) -> str:
        return f"{v:.3e}" if abs(v) < 1e-3 or abs(v) >= 1e4 else f"{v:.4f}"

    cfg_str = ", ".join(f"{k}={v}" for k, v in best_cfg.items())
    print(f"tuned {tun.kernel} via {instance_name}: best {ns.objective} "
          f"= {_fmt(result.best.metrics[ns.objective])} at {cfg_str} "
          f"({len(result.trials)}/{ns.budget} trials, "
          f"strategy {ns.strategy}, seed {ns.seed})")
    if speedup is not None:
        base_str = ", ".join(f"{k}={v}" for k, v in
                             baseline_info["params"].items())
        print(f"  speedup vs builtin default ({base_str}): "
              f"{speedup:.2f}x")
    for axis, span in result.sensitivity:
        print(f"  sensitivity {axis}: {span:.3e}")
    if artifact_path:
        print(f"  artifact: {artifact_path}")
    if report_path:
        print(f"  report:   {report_path}")
    return 0


if __name__ == "__main__":
    sys.exit(tune_main(sys.argv[1:]))
