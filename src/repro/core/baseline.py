"""Baseline storage + regression comparison (GB ``tools/compare.py`` analogue).

Continuous benchmarking needs more than one-shot runs: a stored *baseline*
document and a mean/stddev-aware diff against it.  This module compares two
Google-Benchmark JSON documents (sequential ``run_benchmarks`` output or
the orchestrator's merged shard document — same schema) and produces
per-benchmark verdicts:

  * times are normalized to seconds across time units;
  * repetitions are pooled per ``run_name``: a change is *significant*
    only if the mean shift clears ``sigmas`` pooled standard deviations
    (when repetition data exists) AND the relative change clears
    ``threshold`` — a plain ratio test on noisy single-shot numbers flags
    phantom regressions, which is why GB's compare tool uses U-tests;
  * benchmarks present on only one side are reported as added/removed,
    errored records as errors — never silently dropped.

CLI: ``python -m repro compare BASELINE.json CONTENDER.json`` (also accepts
``results/<run-id>`` directories); exits 1 when regressions are found so it
can gate CI.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import statistics
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .logging import get_logger

log = get_logger("baseline")

_TIME_SCALE = {"ns": 1e-9, "us": 1e-6, "ms": 1e-3, "s": 1.0}

# verdict values
REGRESSION = "regression"
IMPROVEMENT = "improvement"
SIMILAR = "similar"
ADDED = "added"
REMOVED = "removed"
ERRORS = "errors"


@dataclass
class Stats:
    """Pooled repetition statistics for one benchmark run_name.

    Iteration records are the primary source (``times``).  A document
    reduced by ``--aggregates-only`` carries no iteration records, so
    its mean/stddev/repetitions aggregates are kept as a fallback —
    the statistics survive even though the raw repetitions don't.
    """

    times: List[float] = field(default_factory=list)   # seconds
    errors: int = 0
    agg_mean: Optional[float] = None     # seconds, from the aggregates
    agg_stddev: Optional[float] = None
    agg_n: Optional[int] = None

    @property
    def has_times(self) -> bool:
        """True when any timing statistic exists (raw or aggregate)."""
        return bool(self.times) or self.agg_mean is not None

    @property
    def n(self) -> int:
        if self.times:
            return len(self.times)
        return self.agg_n or 0

    @property
    def mean(self) -> float:
        if self.times:
            return statistics.fmean(self.times)
        return self.agg_mean if self.agg_mean is not None else float("nan")

    @property
    def stddev(self) -> float:
        if self.times:
            return statistics.stdev(self.times) if len(self.times) > 1 \
                else 0.0
        return self.agg_stddev or 0.0


@dataclass
class Comparison:
    name: str
    verdict: str
    base_time: Optional[float] = None     # seconds
    new_time: Optional[float] = None
    ratio: Optional[float] = None         # new/base
    significant: bool = False
    note: str = ""


def collect_stats(doc: Dict[str, Any]) -> Dict[str, Stats]:
    """Pool iteration records by ``run_name``.

    Aggregate records are never pooled into ``times`` (that would
    double-count repetitions) but their mean/stddev are kept as the
    fallback statistics for names whose iteration records were dropped
    by ``--aggregates-only``.
    """
    out: Dict[str, Stats] = {}
    for rec in doc.get("benchmarks", []):
        name = rec.get("run_name") or rec.get("name", "")
        st = out.setdefault(name, Stats())
        scale = _TIME_SCALE.get(rec.get("time_unit", "ns"), 1.0)
        if rec.get("run_type") == "aggregate":
            t = rec.get("real_time")
            if t is not None:
                if rec.get("aggregate_name") == "mean":
                    st.agg_mean = t * scale
                elif rec.get("aggregate_name") == "stddev":
                    st.agg_stddev = t * scale
            if rec.get("repetitions"):
                st.agg_n = int(rec["repetitions"])
            continue
        if rec.get("error_occurred") or rec.get("skipped"):
            st.errors += 1
            continue
        t = rec.get("real_time")
        if t is None:
            continue
        st.times.append(t * scale)
    return out


def compare_documents(base: Dict[str, Any], new: Dict[str, Any],
                      threshold: float = 0.10, sigmas: float = 2.0
                      ) -> List[Comparison]:
    """Diff ``new`` against ``base``; returns one Comparison per name."""
    a, b = collect_stats(base), collect_stats(new)
    out: List[Comparison] = []
    for name in sorted(set(a) | set(b)):
        sa, sb = a.get(name), b.get(name)
        if sa is None:
            out.append(Comparison(name, ADDED,
                                  new_time=sb.mean if sb.has_times else None))
            continue
        if sb is None:
            out.append(Comparison(name, REMOVED,
                                  base_time=sa.mean if sa.has_times else None))
            continue
        if not sa.has_times or not sb.has_times:
            which = []
            if not sa.has_times:
                which.append("baseline")
            if not sb.has_times:
                which.append("contender")
            out.append(Comparison(name, ERRORS,
                                  note=f"errored in {'+'.join(which)}"))
            continue
        ma, mb = sa.mean, sb.mean
        ratio = mb / ma if ma > 0 else float("inf")
        rel = (mb - ma) / ma if ma > 0 else float("inf")
        # stddev gate: with repetition data on both sides, require the
        # mean shift to clear `sigmas` pooled standard deviations
        pooled = math.sqrt(sa.stddev ** 2 + sb.stddev ** 2)
        if sa.n > 1 and sb.n > 1 and pooled > 0:
            significant = abs(mb - ma) > sigmas * pooled
        else:
            significant = True          # no noise estimate: ratio decides
        verdict = SIMILAR
        if significant and rel > threshold:
            verdict = REGRESSION
        elif significant and rel < -threshold:
            verdict = IMPROVEMENT
        out.append(Comparison(name, verdict, base_time=ma, new_time=mb,
                              ratio=ratio, significant=significant))
    return out


def summarize(comparisons: List[Comparison]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for c in comparisons:
        counts[c.verdict] = counts.get(c.verdict, 0) + 1
    return counts


def gate_failures(comparisons: List[Comparison]) -> List[Comparison]:
    """Comparisons that must fail a CI gate.

    Regressions, plus benchmarks that were *healthy in the baseline* but
    are missing or errored in the contender — a scope that crashes
    outright produces no contender records, and that must not read as a
    green run.  Benchmarks already broken in the baseline don't count.
    """
    bad = []
    for c in comparisons:
        if c.verdict == REGRESSION:
            bad.append(c)
        elif c.verdict == REMOVED and c.base_time is not None:
            bad.append(c)
        elif c.verdict == ERRORS and c.note == "errored in contender":
            bad.append(c)
    return bad


def _fmt_time(t: Optional[float]) -> str:
    if t is None or math.isnan(t):
        return "-"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if t >= scale:
            return f"{t / scale:.2f}{unit}"
    return f"{t / 1e-9:.0f}ns"


def format_comparisons(comparisons: List[Comparison]) -> str:
    width = max([len(c.name) for c in comparisons] + [9])
    lines = [f"{'benchmark':<{width}}  {'base':>9}  {'new':>9}  "
             f"{'ratio':>6}  verdict"]
    for c in comparisons:
        ratio = f"{c.ratio:.2f}x" if c.ratio is not None else "-"
        verdict = c.verdict.upper() if c.verdict in (REGRESSION,
                                                     IMPROVEMENT) \
            else c.verdict
        note = f"  ({c.note})" if c.note else ""
        lines.append(f"{c.name:<{width}}  {_fmt_time(c.base_time):>9}  "
                     f"{_fmt_time(c.new_time):>9}  {ratio:>6}  "
                     f"{verdict}{note}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# document I/O
# ---------------------------------------------------------------------------

def run_dir_shard_files(path: str) -> List[str]:
    """Shard files of a run directory, in merge order.

    Instance shards (``shards/*.json``, benchmark-grained runs) come
    first, ordered by ``manifest.json``'s plan order when it exists so an
    interrupted run reads back in the same benchmark order its
    ``merged.json`` would have had; scope-grained shards at the top level
    follow, sorted by name.
    """
    out: List[str] = []
    sub = os.path.join(path, "shards")
    if os.path.isdir(sub):
        names = sorted(f for f in os.listdir(sub) if f.endswith(".json"))
        mf = os.path.join(path, "manifest.json")
        if os.path.exists(mf):
            try:
                with open(mf) as f:
                    manifest = json.load(f)
                planned = [os.path.basename(e.get("shard", ""))
                           for e in manifest.get("items", [])]
                have = set(names)
                ordered = [n for n in planned if n in have]
                names = ordered + [n for n in names if n not in set(planned)]
            except (OSError, json.JSONDecodeError):
                pass
        out.extend(os.path.join(sub, n) for n in names)
    out.extend(os.path.join(path, f) for f in sorted(os.listdir(path))
               if f.endswith(".json")
               and f not in ("merged.json", "manifest.json"))
    return out


def load_document(path: str) -> Dict[str, Any]:
    """Load a GB-JSON document; a ``results/<run-id>`` directory works too
    — its ``merged.json`` when present, else the concatenation of its
    shards (a run interrupted before the merge still compares).  Both
    scope-grained (``<scope>.json``) and benchmark-grained
    (``shards/<instance>.json`` + ``manifest.json``) run directories read
    back through the same merged, schema-identical document.

    A ``*.jsonl`` path is read as a run-history file
    (:mod:`repro.core.history`): the last
    :data:`~repro.core.history.DEFAULT_WINDOW` runs of every benchmark
    fold into one synthetic document whose repetitions are the per-run
    means — so ``--baseline results/history.jsonl`` gates against the
    *windowed* history, catching slow drifts that each single-run diff
    called similar.  When a ``history.db`` store index sits next to the
    JSONL (:mod:`repro.store`), the history is read through it instead
    of re-scanned — same records, same verdicts, O(new bytes) cost —
    and any index problem silently falls back to the direct scan."""
    if path.endswith(".jsonl"):
        from .history import window_document
        return window_document(path)
    if os.path.isdir(path):
        merged = os.path.join(path, "merged.json")
        if os.path.exists(merged):
            path = merged
        else:
            shards = run_dir_shard_files(path)
            if not shards:
                raise FileNotFoundError(f"no result JSON in {path}")
            doc: Dict[str, Any] = {"context": {}, "benchmarks": []}
            for shard_path in shards:
                with open(shard_path) as f:
                    shard = json.load(f)
                doc["context"] = doc["context"] or shard.get("context", {})
                doc["benchmarks"].extend(shard.get("benchmarks", []))
            return doc
    with open(path) as f:
        return json.load(f)


def filter_doc_params(doc: Dict[str, Any],
                      param_filter: Optional[Dict[str, List[str]]]
                      ) -> Dict[str, Any]:
    """Keep only records whose name carries matching ``axis:value``
    components (the ``--param`` selection applied to a document where
    only names survive)."""
    if not param_filter:
        return doc
    from .benchmark import match_params, name_params
    return {
        "context": doc.get("context", {}),
        "benchmarks": [
            rec for rec in doc.get("benchmarks", [])
            if match_params(
                name_params(rec.get("run_name") or rec.get("name", "")),
                param_filter)
        ],
    }


def save_baseline(doc: Dict[str, Any], path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    log.info("saved baseline %s (%d records)", path,
             len(doc.get("benchmarks", [])))


# ---------------------------------------------------------------------------
# CLI (python -m repro compare)
# ---------------------------------------------------------------------------

def build_compare_parser() -> argparse.ArgumentParser:
    from .cli_examples import epilog
    ap = argparse.ArgumentParser(
        prog="python -m repro compare",
        description="Compare two benchmark result documents "
                    "(JSON file, results/<run-id> directory, or a "
                    "history.jsonl windowed baseline)",
        epilog=epilog("compare"),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline", help="baseline JSON file, run directory, "
                                     "or history.jsonl")
    ap.add_argument("contender", help="contender JSON file or run directory")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative change needed to flag (default 0.10)")
    ap.add_argument("--sigmas", type=float, default=2.0,
                    help="pooled-stddev multiple the mean shift must clear "
                         "when repetition data exists (default 2.0)")
    ap.add_argument("--param", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="compare only instances whose name carries the "
                         "typed parameter KEY:VALUE (repeatable)")
    return ap


def compare_main(argv: Optional[List[str]] = None) -> int:
    from .benchmark import parse_param_filter
    ns = build_compare_parser().parse_args(argv)
    try:
        param_filter = parse_param_filter(ns.param)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    try:
        base = filter_doc_params(load_document(ns.baseline), param_filter)
        new = filter_doc_params(load_document(ns.contender), param_filter)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    comps = compare_documents(base, new,
                              threshold=ns.threshold, sigmas=ns.sigmas)
    if not comps:
        print("no benchmarks to compare")
        return 0
    print(format_comparisons(comps))
    counts = summarize(comps)
    print()
    print("summary:", ", ".join(f"{v} {k}" for k, v in sorted(counts.items())))
    bad = gate_failures(comps)
    if bad:
        print(f"gate: {len(bad)} failure(s) — "
              + ", ".join(f"{c.name} [{c.verdict}]" for c in bad[:10]))
    return 1 if bad else 0
