"""Version-tolerant wrappers over moving JAX APIs.

``shard_map`` has lived in three places across JAX releases:

  * ``jax.experimental.shard_map.shard_map(..., check_rep=...)`` (<= 0.4.x)
  * ``jax.shard_map(..., check_rep=...)`` (0.5.x)
  * ``jax.shard_map(..., check_vma=...)`` (>= 0.6, keyword renamed)

Model and scope code must not care which JAX the container bakes in, so
they import :func:`shard_map` from here.  The replication-check keyword is
normalized to ``check`` and translated to whatever the installed JAX
spells it.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax


def _resolve_shard_map() -> Callable[..., Any]:
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # type: ignore
    return sm


_SHARD_MAP = _resolve_shard_map()
try:
    _PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)
except (TypeError, ValueError):  # builtins / C-accelerated: assume modern
    _PARAMS = frozenset({"check_vma"})


def shard_map(f: Callable[..., Any], *, mesh, in_specs, out_specs,
              check: bool = True) -> Callable[..., Any]:
    """SPMD-map ``f`` over ``mesh`` — portable across JAX versions.

    ``check`` is the replication/varying-manual-axes check
    (``check_rep`` on older JAX, ``check_vma`` on newer).
    """
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _PARAMS:
        kwargs["check_vma"] = check
    elif "check_rep" in _PARAMS:
        kwargs["check_rep"] = check
    return _SHARD_MAP(f, **kwargs)
