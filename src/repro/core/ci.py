"""``python -m repro ci`` — the continuous-benchmarking entrypoint.

One command, per commit (the ROOT continuous-performance-framework
service loop, exaCB's incremental collections):

  1. **delta-plan** — compute every selected instance's fingerprint
     (:mod:`repro.core.fingerprint`) and prune the ones whose current
     fingerprint already has a measured history record on this machine;
     a no-change commit plans zero instances;
  2. **run** — execute the remaining instances through the orchestrator
     (``--shard-grain benchmark``); skipped instances replay their
     latest records into the merged document as ``cached: true`` so the
     document stays complete;
  3. **append** — history records land tagged ``ci`` with their
     fingerprints (replays marked ``cached``, excluded from pooling);
  4. **gate** — the freshly-measured instances are judged against the
     windowed run history (:func:`repro.core.history.detect_drift`, the
     same pooled cross-run stddev ``repro compare`` uses);
  5. **report** — the static HTML/Markdown report re-renders
     (best-effort; a report failure never masks a gate verdict).

Exit codes: **0** clean (including "nothing changed"), **1** regression
or failed instances, **2** usage error.  Cookbook:
docs/continuous-benchmarking.md.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from . import logging as scope_logging
from .baseline import format_comparisons, gate_failures, summarize
from .benchmark import parse_param_filter
from .cli_examples import epilog
from .flags import FLAGS
from .history import DEFAULT_WINDOW, detect_drift, history_path, load_history
from .orchestrate import OK, OrchestratorOptions, execute
from .registry import REGISTRY
from .runner import RunOptions

log = scope_logging.get_logger("ci")


def build_ci_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro ci",
                                 add_help=False, epilog=epilog("ci"),
                                 formatter_class=
                                 argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--enable-scope", action="append", default=None,
                    help="enable ONLY these scopes (repeatable)")
    ap.add_argument("--disable-scope", action="append", default=[],
                    help="disable these scopes (repeatable)")
    ap.add_argument("--param", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="gate only instances whose typed parameter KEY "
                         "equals VALUE (repeatable)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="run the delta plan in N isolated workers")
    ap.add_argument("--results-dir", default="results",
                    help="run history + run artifacts location "
                         "(default: results)")
    ap.add_argument("--run-id", default=None,
                    help="run directory name (default: timestamp)")
    ap.add_argument("--full", action="store_true",
                    help="skip delta planning: re-measure every "
                         "instance regardless of fingerprint freshness")
    ap.add_argument("--since", default="", metavar="ISO",
                    help="records older than this ISO prefix don't "
                         "count as fresh (default: any measured record "
                         "with the current fingerprint does)")
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW,
                    help=f"prior runs pooled for the drift gate "
                         f"(default {DEFAULT_WINDOW})")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative mean-shift the gate tolerates "
                         "(default: %(default)s)")
    ap.add_argument("--sigmas", type=float, default=2.0,
                    help="pooled-stddev significance bar "
                         "(default: %(default)s)")
    ap.add_argument("--no-report", action="store_true",
                    help="skip re-rendering the static report")
    return ap


def ci_main(argv: List[str],
            scope_modules: Optional[List[str]] = None) -> int:
    ap = build_ci_parser()
    if any(a in ("-h", "--help") for a in argv):
        print(ap.format_help())
        return 0
    ns, rest = ap.parse_known_args(argv)

    try:
        param_filter = parse_param_filter(ns.param)
    except ValueError as e:
        log.error("%s", e)
        return 2
    if not ns.results_dir:
        log.error("repro ci needs a --results-dir (history is both the "
                  "freshness source and the drift baseline)")
        return 2

    from .main import _delta_cached, _setup_scopes
    mgr, rc = _setup_scopes(scope_modules, ns.enable_scope,
                            ns.disable_scope, rest)
    if mgr is None:
        return rc
    mgr.register_all()

    pattern = FLAGS.get("benchmark_filter", ".*")
    benches = REGISTRY.filter(pattern, params=param_filter)
    if not benches:
        log.error("no benchmarks match %r%s", pattern,
                  f" with --param {ns.param}" if param_filter else "")
        return 2
    from .fingerprint import registry_fingerprints
    from .plan import scope_worklist
    fingerprints = registry_fingerprints(benches)

    cached = {}
    if not ns.full:
        cached = _delta_cached(mgr, ns.results_dir, pattern, param_filter,
                               fingerprints, ns.since)

    # workers for scopes with nothing to run would pay a JAX import each
    matched = {b.scope for b in benches}
    mgr.configure(disable=[name for name, _ in scope_worklist(mgr)
                           if name not in matched])

    opts = OrchestratorOptions(
        jobs=ns.jobs,
        shard_grain="benchmark",
        benchmark_filter=pattern,
        run=RunOptions(
            min_time=FLAGS.get("benchmark_min_time", 0.05),
            repetitions=FLAGS.get("benchmark_repetitions", 1),
            param_filter=param_filter,
        ),
        flag_values={s.name: FLAGS.get(s.name) for s in FLAGS.declared()},
        results_dir=ns.results_dir,
        run_id=ns.run_id,
        cached_results=cached,
        history_tag="ci",
    )
    result = execute(mgr, REGISTRY, opts,
                     context_extra={"scopes": mgr.status(),
                                    "fingerprints": fingerprints,
                                    "ci": True})
    measured = [r for r in result.instances if not r.cached]
    failed = [r for r in measured if r.status != OK]
    log.info("ci run %s: %d instance(s) measured, %d cached, "
             "%d failed", result.run_id, len(measured),
             len(result.instances) - len(measured), len(failed))

    # gate: freshly-measured instances vs the windowed history
    comps = detect_drift(load_history(history_path(ns.results_dir)),
                         window=ns.window, threshold=ns.threshold,
                         sigmas=ns.sigmas)
    failures = gate_failures(comps)
    if comps:
        print(format_comparisons(comps), file=sys.stderr)
        counts = summarize(comps)
        log.info("drift gate: %s",
                 ", ".join(f"{v} {k}" for k, v in sorted(counts.items())))
    else:
        log.info("drift gate: nothing to judge (no re-measured "
                 "instances, or fewer than two runs in history)")

    if not ns.no_report and result.out_dir:
        try:
            from repro.scopeplot.report import report_main
            report_main([result.run_id, "--results-dir", ns.results_dir])
        except Exception:  # noqa: BLE001 - the verdict must not depend
            # on rendering; the gate already decided
            log.warning("report rendering failed for %s (gate verdict "
                        "unaffected)", result.run_id, exc_info=True)

    if failed:
        log.error("ci: %d instance(s) failed: %s", len(failed),
                  ", ".join(r.item.name for r in failed[:8]))
        return 1
    if failures:
        log.error("ci: drift gate failed (%d regression(s)/loss(es))",
                  len(failures))
        return 1
    print(f"ci: ok — {len(measured)} measured, "
          f"{len(result.instances) - len(measured)} cached, "
          f"run {result.run_id}")
    return 0
