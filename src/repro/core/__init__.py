"""repro.core — the SCOPE repository analogue (paper §III).

The paper's primary contribution: a thin benchmark-free core providing
registration, configuration, uniform utilities, init hooks, and uniform
JSON reporting for independently-developed benchmark groups ("scopes").

Public API surface for scope authors::

    from repro.core import benchmark, State, Scope, FLAGS

    def _register(registry):
        @benchmark(scope="myscope", registry=registry)
        def my_bench(state: State):
            while state.keep_running():
                ...

    SCOPE = Scope(name="myscope", register=_register)
"""
from .benchmark import Benchmark, State, SkipError
from .errorcheck import (ScopeError, check_compiles, check_finite,
                         check_shape, check_sharding, checked, sync)
from .flags import FLAGS, FlagRegistry
from .hooks import HOOKS, HookChain
from .logging import get_logger
from .baseline import Comparison, compare_documents, save_baseline
from .orchestrate import (InstanceResult, OrchestratorOptions, RunResult,
                          ScopeShard, execute, merge_shards)
from .plan import Plan, PlanItem, build_plan, load_cost_hints
from .registry import (REGISTRY, BenchmarkRegistry, benchmark,
                       register_benchmark)
from .runner import (RunOptions, run_benchmarks, run_single_instance,
                     write_json)
from .scope import BUILTIN_SCOPES, Scope, ScopeManager
from .sysinfo import TPU_V5E, build_context

__all__ = [
    "Benchmark", "State", "SkipError",
    "ScopeError", "check_compiles", "check_finite", "check_shape",
    "check_sharding", "checked", "sync",
    "FLAGS", "FlagRegistry", "HOOKS", "HookChain", "get_logger",
    "REGISTRY", "BenchmarkRegistry", "benchmark", "register_benchmark",
    "RunOptions", "run_benchmarks", "run_single_instance", "write_json",
    "BUILTIN_SCOPES", "Scope", "ScopeManager",
    "Plan", "PlanItem", "build_plan", "load_cost_hints",
    "InstanceResult", "OrchestratorOptions", "RunResult", "ScopeShard",
    "execute", "merge_shards", "Comparison", "compare_documents",
    "save_baseline",
    "TPU_V5E", "build_context",
]
