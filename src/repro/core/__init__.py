"""repro.core — the SCOPE repository analogue (paper §III).

The paper's primary contribution: a thin benchmark-free core providing
registration, configuration, uniform utilities, init hooks, and uniform
JSON reporting for independently-developed benchmark groups ("scopes").

Public API surface for scope authors::

    from repro.core import ParamSpace, Scope, State, benchmark

    def _register(registry):
        @benchmark(scope="myscope", registry=registry)
        def my_bench(state: State):
            x = state.fixture                  # from set_fixture(setup)
            while state.keep_running():
                ...
        my_bench.param_space(dtype=["f32", "bf16"], n=[256, 1024])
        my_bench.set_fixture(lambda params: make_input(params))

    SCOPE = Scope(name="myscope", register=_register)
"""
from .benchmark import (Benchmark, ParamSpace, Params, State, SkipError,
                        Tunable, match_params, parse_param_filter)
from .errorcheck import (ScopeError, check_compiles, check_finite,
                         check_shape, check_sharding, checked, sync)
from .flags import FLAGS, FlagRegistry
from .hooks import HOOKS, HookChain
from .logging import get_logger
from .measure import (CostModelMeter, CpuTimeMeter, DEFAULT_METERS, METERS,
                      Meter, MeterStack, WallClockMeter, parse_meters)
from .baseline import Comparison, compare_documents, save_baseline
from .orchestrate import (InstanceResult, OrchestratorOptions, RunResult,
                          ScopeShard, execute, merge_shards)
from .plan import Plan, PlanItem, build_plan, load_cost_hints
from .registry import (REGISTRY, BenchmarkRegistry, benchmark,
                       register_benchmark)
from .runner import (RunOptions, run_benchmarks, run_single_instance,
                     write_json)
from .scope import BUILTIN_SCOPES, Scope, ScopeManager
from .search import (STRATEGIES, SearchResult, Trial, TrialError,
                     pareto_front, run_search)
from .sysinfo import TPU_V5E, build_context

__all__ = [
    "Benchmark", "ParamSpace", "Params", "State", "SkipError",
    "Tunable", "match_params", "parse_param_filter",
    "ScopeError", "check_compiles", "check_finite", "check_shape",
    "check_sharding", "checked", "sync",
    "FLAGS", "FlagRegistry", "HOOKS", "HookChain", "get_logger",
    "Meter", "MeterStack", "WallClockMeter", "CpuTimeMeter",
    "CostModelMeter", "METERS", "DEFAULT_METERS", "parse_meters",
    "REGISTRY", "BenchmarkRegistry", "benchmark", "register_benchmark",
    "RunOptions", "run_benchmarks", "run_single_instance", "write_json",
    "BUILTIN_SCOPES", "Scope", "ScopeManager",
    "Plan", "PlanItem", "build_plan", "load_cost_hints",
    "InstanceResult", "OrchestratorOptions", "RunResult", "ScopeShard",
    "execute", "merge_shards", "Comparison", "compare_documents",
    "save_baseline",
    "STRATEGIES", "SearchResult", "Trial", "TrialError", "pareto_front",
    "run_search",
    "TPU_V5E", "build_context",
]
