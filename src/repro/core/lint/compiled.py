"""Compile-tier analysis: lower the fixture's workload, read the HLO.

The ``benchmark::DoNotOptimize`` class of bugs — XLA constant-folding
or dead-code-eliminating the thing the author believes they are timing
— is invisible to AST inspection: the source *looks* like it computes.
This tier detects it instead of working around it: the fixture's
``(jitted_fn, *operands)`` is lowered and compiled **once** per
representative instance (the body is never called, nothing is timed)
and the *optimized* HLO text is diffed against what the author handed
the compiler:

  * a workload whose optimized module contains **no compute
    instructions** (only parameters/constants/copies/tuples) was folded
    away or reduced to a data movement — its timings measure XLA's
    copy path, not the op;
  * operand leaves that never become entry parameters were dead-code
    -eliminated at trace time — the benchmark sweeps an axis the
    compiled workload does not consume.

Shares the fixture-context convention (``(callable, *operands)``) and
the HLO text analyzer with the cost-model meter
(:func:`repro.core.measure.fixture_call`,
:mod:`repro.roofline.hlo`), so what the linter certifies is exactly
what the meters will later measure.
"""
from __future__ import annotations

import sys
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import List, Optional

from ..logging import get_logger

log = get_logger("lint")

#: HLO opcodes that move or stage data without computing anything — a
#: module containing only these does no work worth timing.
PASSIVE_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "copy",
    "copy-start", "copy-done", "bitcast", "after-all", "partition-id",
    "replica-id",
})


@dataclass
class CompiledWorkload:
    """What one family's fixture workload compiled down to."""

    instance: str                    # representative instance name
    convention: bool = True          # ctx followed (callable, *operands)
    error: str = ""                  # fixture/lower/compile failure
    hlo_text: str = ""
    compute_ops: int = 0             # non-passive instructions, all comps
    entry_params: int = 0            # entry computation parameters
    operand_leaves: int = 0          # array leaves handed to the callable
    flops: float = 0.0               # repro.roofline.hlo estimate
    passive_only_ops: List[str] = field(default_factory=list)

    def analyzed(self) -> bool:
        return bool(self.hlo_text) and not self.error


def _count_leaves(args) -> int:
    jax = sys.modules.get("jax")
    if jax is None:
        return len(args)
    return len(jax.tree_util.tree_leaves(args))


def compile_workload(bench) -> Optional[CompiledWorkload]:
    """Lower + compile the fixture's workload for the family's first
    instance; return its :class:`CompiledWorkload` (None when there is
    no fixture or no instance to represent the family).

    Only ``fixture(params)``, ``lower`` and ``compile`` run — never the
    benchmark body, never a timed repetition.  Failures are recorded on
    the result (``error``) rather than raised: the trace tier degrades
    per family exactly like the cost-model meter does.
    """
    if bench.fixture is None:
        return None
    instances = bench.instances()
    if not instances:
        return None
    name, params = instances[0]
    out = CompiledWorkload(instance=name)
    try:
        ctx = bench.fixture(params)
    except Exception as e:  # noqa: BLE001 - report, don't crash the pass
        out.error = f"fixture failed: {e!r}"
        return out
    from ..measure import fixture_call
    call = fixture_call(SimpleNamespace(fixture=ctx))
    if call is None:
        out.convention = False
        return out
    fn, args = call
    jax = sys.modules.get("jax")
    if jax is None:
        out.error = "jax not loaded; nothing to lower"
        return out
    try:
        lowered = fn.lower(*args) if hasattr(fn, "lower") \
            else jax.jit(fn).lower(*args)
        out.hlo_text = lowered.compile().as_text()
    except Exception as e:  # noqa: BLE001
        out.error = f"would not lower/compile: {e!r}"
        return out
    out.operand_leaves = _count_leaves(args)
    _analyze_text(out)
    return out


def _analyze_text(out: CompiledWorkload) -> None:
    from repro.roofline.hlo import analyze_hlo, parse_module
    comps = parse_module(out.hlo_text)
    ops: List[str] = []
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode not in PASSIVE_OPS:
                ops.append(ins.opcode)
    out.compute_ops = len(ops)
    if not ops:
        seen: List[str] = []
        for comp in comps.values():
            for ins in comp.instrs.values():
                if ins.opcode not in seen:
                    seen.append(ins.opcode)
        out.passive_only_ops = seen
    entry = None
    for comp_name, comp in comps.items():
        if "main" in comp_name:
            entry = comp
            break
    if entry is not None:
        out.entry_params = sum(1 for ins in entry.instrs.values()
                               if ins.opcode == "parameter")
    try:
        out.flops = analyze_hlo(out.hlo_text).flops
    except Exception as e:  # noqa: BLE001 - flops are advisory here
        log.debug("lint: flops analysis failed for %s: %s",
                  out.instance, e)
