"""``python -m repro lint`` — the static-analysis front door.

Loads and registers scopes exactly like ``run`` would (same flag
parsing, same init hooks, same registry), then hands the registered
families to :func:`repro.core.lint.run_lint` instead of the
orchestrator.  No benchmark body runs; nothing is timed.

Exit codes follow the rest of the binary: 0 clean, 1 findings gate
(errors; warnings too under ``--strict``), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .. import logging as scope_logging
from ..cli_examples import epilog
from ..flags import FLAGS
from ..hooks import HOOKS
from ..registry import REGISTRY
from ..scope import ScopeManager
from .framework import RULES, LintReport, parse_rules, run_lint

log = scope_logging.get_logger("lint")


def build_lint_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro lint",
                                 add_help=False, epilog=epilog("lint"),
                                 formatter_class=
                                 argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scope", action="append", default=None,
                    metavar="NAME",
                    help="lint ONLY these scopes (repeatable; default: "
                         "every enabled scope)")
    ap.add_argument("--family", default=None, metavar="REGEX",
                    help="lint only families whose registered name "
                         "matches REGEX")
    ap.add_argument("--rules", default=None, metavar="LIST",
                    help="comma-separated rule ids to run (default: all; "
                         "see --list-rules)")
    ap.add_argument("--format", default="text", choices=["text", "json"],
                    help="finding output format (json is the machine "
                         "contract consumed by CI)")
    ap.add_argument("--strict", action="store_true",
                    help="fail (exit 1) on warnings as well as errors")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the trace-tier rules that lower and "
                         "compile fixture workloads (AST and registry "
                         "tiers still run)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    return ap


def list_rules() -> str:
    lines = []
    for rule_id in sorted(RULES):
        rule = RULES[rule_id]()
        title = rule.title or (rule.__doc__ or "").strip().splitlines()[0]
        tier = " (compile tier)" if rule.requires_compile else ""
        lines.append(f"{rule_id}  {rule.severity:<7s} {title}{tier}")
    return "\n".join(lines)


def render(report: LintReport, fmt: str, strict: bool) -> str:
    if fmt == "json":
        doc = report.to_json()
        doc["failed"] = report.failed(strict)
        return json.dumps(doc, indent=2, sort_keys=True)
    return report.format_text()


def lint_main(argv: List[str],
              scope_modules: Optional[List[str]] = None) -> int:
    ap = build_lint_parser()
    if any(a in ("-h", "--help") for a in argv):
        print(ap.format_help())
        return 0
    ns, rest = ap.parse_known_args(argv)
    if ns.list_rules:
        print(list_rules())
        return 0

    rule_ids = None
    if ns.rules:
        try:
            rule_ids = parse_rules(ns.rules)
        except ValueError as e:
            log.error("%s", e)
            return 2

    # Same startup sequence as run/plan (scope flags, init hooks) so a
    # family registered conditionally on a flag is linted exactly as it
    # would be run.
    mgr = ScopeManager()
    mgr.load(scope_modules)
    rc = HOOKS.run_pre_parse()
    if rc is not None:
        return rc
    FLAGS.parse(rest)
    scope_logging.set_level(FLAGS.get("log_level", "INFO"))
    rc = HOOKS.run_post_parse()
    if rc is not None:
        return rc
    mgr.configure(enable=ns.scope)
    mgr.register_all()

    scope_names = sorted(name for name, status in mgr.status().items()
                         if status == "enabled")
    pattern = ns.family or FLAGS.get("benchmark_filter", ".*")
    benches = [b for b in REGISTRY.filter(pattern)
               if b.scope in set(scope_names)]
    if ns.family:
        if not benches:
            log.error("no families match %r", ns.family)
            return 2
        # a family filter makes unselected scopes look empty — don't
        # let the empty-scope rule cry wolf about them
        scope_names = sorted({b.scope for b in benches})

    report = run_lint(benches, scope_names=scope_names, rules=rule_ids,
                      compile_checks=not ns.no_compile)
    print(render(report, ns.format, ns.strict))
    return 1 if report.failed(ns.strict) else 0


if __name__ == "__main__":
    sys.exit(lint_main(sys.argv[1:]))
