"""Built-in registry-tier rules (SCOPE3xx): cross-family consistency.

These look at the registry/plan as a whole — sweeps that collapse onto
duplicate points, names that cannot resolve uniquely, scopes and
families that schedule nothing.
"""
from __future__ import annotations

from typing import Dict, Iterable

from .framework import FamilyContext, FamilyRule, Finding, LintContext, \
    RegistryRule, register_rule


@register_rule
class DuplicateAdjacentPoints(FamilyRule):
    """Instances that are identical once dead axes are projected out."""

    id = "SCOPE301"
    severity = "warning"
    title = ""
    fix_hint = ("remove the dead axis (or read it); until then the plan "
                "schedules the same workload under several names")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        for first, dupe in fam.analysis.live_projection_duplicates():
            yield self.finding(
                fam,
                message=(f"instances {first!r} and {dupe!r} differ only "
                         f"along dead parameter axes — they measure the "
                         f"identical workload twice"))


@register_rule
class InstanceNameCollision(RegistryRule):
    """Two families emit the same instance name.

    The plan keys cost hints, resume shards and baseline joins by
    instance name; a collision means those lookups can never resolve
    (build_plan refuses to schedule such a registry at all).
    """

    id = "SCOPE302"
    severity = "error"
    title = ""
    fix_hint = ("rename one family, or disambiguate the sweeps — "
                "instance names key cost hints, resume state and "
                "baseline comparisons")

    def check_registry(self, ctx: LintContext) -> Iterable[Finding]:
        owners: Dict[str, FamilyContext] = {}
        for fam in ctx.families:
            try:
                instances = fam.bench.instances()
            except Exception:  # noqa: BLE001 - SCOPE303 owns broken sweeps
                continue
            for name, _params in instances:
                prev = owners.get(name)
                if prev is None:
                    owners[name] = fam
                elif prev.bench.name != fam.bench.name:
                    yield self.finding(
                        fam,
                        message=(f"instance name {name!r} is emitted by "
                                 f"both {prev.bench.name!r} and "
                                 f"{fam.bench.name!r} — cost hints and "
                                 f"resume shards cannot resolve it"))


@register_rule
class EmptySweep(RegistryRule):
    """Families with zero instances; scopes registering no families."""

    id = "SCOPE303"
    severity = "warning"
    title = ""
    fix_hint = ("check the ParamSpace filters (.where) and the scope's "
                "register() hook — an empty sweep silently drops out of "
                "every plan and report")

    def check_registry(self, ctx: LintContext) -> Iterable[Finding]:
        populated = set()
        for fam in ctx.families:
            populated.add(fam.scope)
            try:
                count = len(fam.bench.instances())
            except Exception as e:  # noqa: BLE001
                yield self.finding(
                    fam,
                    message=(f"sweep could not be expanded ({e!r}) — the "
                             f"family contributes nothing to any plan"))
                continue
            if count == 0:
                yield self.finding(
                    fam,
                    message=("family expands to zero instances — it is "
                             "registered but can never be scheduled"))
        for scope in ctx.scope_names:
            if scope not in populated:
                yield self.finding(
                    scope=scope,
                    message=(f"scope {scope!r} registered no benchmark "
                             f"families — nothing to measure"))
