"""AST-tier analysis of one benchmark family's body and fixture.

Works entirely from the source captured at registration time
(``Benchmark.source`` / ``fixture_source``, falling back to
``inspect.getsource``): nothing is imported, called, traced or timed.

The central objects:

  * :func:`parse_function` — source text → the ``ast.FunctionDef`` of
    the body/fixture (decorators and nesting indentation handled);
  * :class:`FamilyAnalysis` — every per-family fact the AST rules
    consume: the timed loops (``while state.keep_running():`` /
    ``for _ in state:``), the calls made inside them, whether the body
    declares deliverables or counters, and which parameter axes the
    body + fixture actually *read*;
  * :class:`AxisReads` — the read-set with an honesty bit: any dynamic
    access the analyzer cannot resolve (``state.params`` passed whole
    to a helper, a non-constant subscript) flips ``known`` off, and
    rules that depend on the read-set skip the family instead of
    guessing (a linter that cries wolf gets turned off).
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain; None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def parse_function(source: Optional[str]) -> Optional[ast.FunctionDef]:
    """The first function definition in ``source`` (None if unparseable
    — e.g. a lambda registered imperatively, or source lost)."""
    if not source:
        return None
    try:
        tree = ast.parse(textwrap.dedent(source))
    except (SyntaxError, ValueError):
        return None
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return node
    return None


def _get_source(obj: Any) -> Optional[str]:
    try:
        return inspect.getsource(obj)
    except (OSError, TypeError):
        return None


@dataclass
class AxisReads:
    """Which parameter axes a function reads — and whether the analyzer
    could actually tell (``known=False`` → treat every axis as read)."""

    names: Set[str] = field(default_factory=set)
    known: bool = True


@dataclass
class CallSite:
    """One call made somewhere in the body, as a dotted name."""

    name: str
    line: int


def int_axis_names(bench) -> List[str]:
    """Axis names that ``state.range(i)`` indexes, in order (the
    int-valued axes of the first point for typed families, the declared
    arg names for legacy families)."""
    if bench.space is not None:
        pts = bench.space.points()
        if not pts:
            return []
        return [k for k, v in pts[0].items()
                if isinstance(v, int) and not isinstance(v, bool)]
    return list(bench.arg_names)


def declared_axes(bench) -> List[str]:
    """The axes an author *declared*: the typed space's axes, or a legacy
    family's named args.  Unnamed legacy sweeps declare nothing
    addressable, so dead-axis analysis skips them."""
    if bench.space is not None:
        return bench.space.axes()
    if bench.arg_names and bench.arg_sets \
            and len(bench.arg_names) == len(bench.arg_sets[0]):
        return list(bench.arg_names)
    return []


class FamilyAnalysis:
    """Lazily-computed AST facts about one family (body + fixture)."""

    def __init__(self, bench):
        self.bench = bench
        self.body = parse_function(bench.source or _get_source(bench.fn))
        fixture_src = bench.fixture_source
        if fixture_src is None and bench.fixture is not None:
            fixture_src = _get_source(bench.fixture)
        self.fixture = parse_function(fixture_src)
        self.state_arg: Optional[str] = None
        if self.body is not None and self.body.args.args:
            self.state_arg = self.body.args.args[0].arg
        self.timed_loops: List[ast.AST] = []
        if self.body is not None and self.state_arg:
            self.timed_loops = [n for n in ast.walk(self.body)
                                if self._is_timed_loop(n)]

    # -- structure -----------------------------------------------------
    def _is_timed_loop(self, node: ast.AST) -> bool:
        state = self.state_arg
        if isinstance(node, ast.While):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.Call) and \
                        dotted_name(sub.func) == f"{state}.keep_running":
                    return True
        if isinstance(node, ast.For):
            it = node.iter
            if dotted_name(it) == state:
                return True
            if isinstance(it, ast.Call) and dotted_name(it.func) == "iter" \
                    and it.args and dotted_name(it.args[0]) == state:
                return True
        return False

    def analyzable(self) -> bool:
        """Could the body be parsed into something rule-worthy?"""
        return self.body is not None and self.state_arg is not None

    # -- calls ---------------------------------------------------------
    def _calls_in(self, nodes) -> Iterator[ast.Call]:
        for root in nodes:
            for sub in ast.walk(root):
                if isinstance(sub, ast.Call):
                    yield sub

    def body_calls(self) -> List[CallSite]:
        """Every dotted-name call anywhere in the body."""
        if self.body is None:
            return []
        return [CallSite(name, c.lineno)
                for c in self._calls_in([self.body])
                if (name := dotted_name(c.func))]

    def timed_region_calls(self) -> List[CallSite]:
        """Every dotted-name call inside a timed loop's body — the code
        that runs with the clock running."""
        stmts: List[ast.AST] = []
        for loop in self.timed_loops:
            stmts.extend(loop.body)
        return [CallSite(name, c.lineno) for c in self._calls_in(stmts)
                if (name := dotted_name(c.func))]

    def calls_state_method(self, method: str) -> bool:
        """Does the body call ``state.<method>(...)`` anywhere?"""
        if not self.state_arg:
            return False
        target = f"{self.state_arg}.{method}"
        return any(c.name == target for c in self.body_calls())

    def sets_counters(self) -> bool:
        """Does the body assign into ``state.counters[...]``?"""
        if self.body is None or not self.state_arg:
            return False
        target = f"{self.state_arg}.counters"
        for node in ast.walk(self.body):
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, ast.Store) \
                    and dotted_name(node.value) == target:
                return True
        return False

    # -- parameter-axis reads -------------------------------------------
    def _reads(self, func: ast.FunctionDef, roots: Set[str],
               bench) -> AxisReads:
        """Axes read through any expression in ``roots`` (dotted names
        that evaluate to the family's ``Params``), following simple
        ``alias = state.params`` assignments."""
        reads = AxisReads()
        parents: Dict[ast.AST, ast.AST] = {
            child: parent for parent in ast.walk(func)
            for child in ast.iter_child_nodes(parent)}
        roots = set(roots)
        # alias fixpoint: p = state.params; q = p; ...
        changed = True
        while changed:
            changed = False
            for node in ast.walk(func):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and dotted_name(node.value) in roots \
                        and node.targets[0].id not in roots:
                    roots.add(node.targets[0].id)
                    changed = True
        ints = int_axis_names(bench)
        state = self.state_arg
        for node in ast.walk(func):
            # state.range(i) / state.ranges read the int-valued axes
            if state is not None and isinstance(node, ast.Call):
                if dotted_name(node.func) == f"{state}.range":
                    idx = node.args[0] if node.args else ast.Constant(0)
                    if isinstance(idx, ast.Constant) \
                            and isinstance(idx.value, int) \
                            and 0 <= idx.value < len(ints):
                        reads.names.add(ints[idx.value])
                    else:
                        reads.known = False
                    continue
            if state is not None and isinstance(node, ast.Attribute) \
                    and node.attr == "ranges" \
                    and dotted_name(node.value) == state:
                reads.names.update(ints)
                continue
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            if isinstance(node.ctx, ast.Store):
                continue
            if dotted_name(node) not in roots:
                continue
            parent = parents.get(node)
            if isinstance(parent, ast.Attribute) and parent.value is node:
                reads.names.add(parent.attr)
            elif isinstance(parent, ast.Subscript) and parent.value is node:
                key = parent.slice
                if isinstance(key, ast.Constant) and isinstance(key.value, str):
                    reads.names.add(key.value)
                else:
                    reads.known = False
            elif isinstance(parent, ast.Assign) and node is parent.value \
                    and len(parent.targets) == 1 \
                    and isinstance(parent.targets[0], ast.Name):
                pass  # simple alias, already folded into roots
            else:
                # params escapes (helper call, iteration, f-string...):
                # the analyzer cannot see which axes that code reads
                reads.known = False
        return reads

    def axis_reads(self) -> AxisReads:
        """Union of the axes the body and the fixture read.  ``known``
        is False as soon as either side does something the analyzer
        cannot resolve — or when either source was unavailable."""
        out = AxisReads()
        if self.body is None or self.state_arg is None:
            out.known = False
            return out
        body = self._reads(self.body, {f"{self.state_arg}.params"},
                           self.bench)
        out.names |= body.names
        out.known &= body.known
        if self.bench.fixture is not None:
            if self.fixture is None or not self.fixture.args.args:
                out.known = False
                return out
            fixture = self._reads(self.fixture,
                                  {self.fixture.args.args[0].arg},
                                  self.bench)
            out.names |= fixture.names
            out.known &= fixture.known
        return out

    def dead_axes(self) -> Optional[List[str]]:
        """Declared-but-never-read axes (None = analysis inconclusive,
        rules must stay quiet)."""
        declared = declared_axes(self.bench)
        if not declared:
            return []
        reads = self.axis_reads()
        if not reads.known:
            return None
        return [a for a in declared if a not in reads.names]

    def live_projection_duplicates(self) -> List[Tuple[str, str]]:
        """Instance-name pairs that collapse onto the same point once
        dead axes are projected out — i.e. instances that measure the
        identical workload twice."""
        dead = self.dead_axes()
        if not dead:
            return []
        seen: Dict[Tuple, str] = {}
        dupes: List[Tuple[str, str]] = []
        for name, params in self.bench.instances():
            key = tuple((k, v) for k, v in params.items() if k not in dead)
            if key in seen:
                dupes.append((seen[key], name))
            else:
                seen[key] = name
        return dupes
