"""Built-in AST-tier rules (SCOPE0xx/SCOPE1xx): source-level hazards.

Each rule names a way a benchmark silently measures the wrong thing.
The catalog (ids, what the hazard does to the numbers, and how to fix
each one) is docs/linting.md; tests/test_lint.py keeps one triggering
and one clean family per rule.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable

from .framework import FamilyContext, FamilyRule, Finding, LintContext, \
    RegistryRule, register_rule
from .analysis import dotted_name

#: Array-constructor / compile entry points that belong in a fixture —
#: inside the timed loop they bill allocation/trace/compile time to the
#: workload.  Keyed by full dotted call name as written in the body.
_MODULE_ALIASES = ("np", "numpy", "jnp", "jax.numpy")
_ALLOC_FNS = ("ones", "zeros", "full", "empty", "arange", "linspace",
              "eye", "ones_like", "zeros_like", "asarray", "array")
TIMED_REGION_BANNED = frozenset(
    {f"{mod}.{fn}" for mod in _MODULE_ALIASES for fn in _ALLOC_FNS}
    | {"jax.jit", "jax.grad", "jax.vmap", "jax.pmap", "jax.value_and_grad",
       "jax.make_mesh", "jax.device_put",
       "jax.random.PRNGKey", "jax.random.key", "jax.random.normal",
       "jax.random.uniform", "jax.random.randint", "jax.random.split"})

#: Host clocks a body must never read — the meter stack owns timing
#: (manual-time families are the sanctioned exception).
WALL_CLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
})


@register_rule
class UnanalyzableFamily(FamilyRule):
    """Source unavailable/unparseable → the AST tier is flying blind."""

    id = "SCOPE000"
    severity = "info"
    title = ("benchmark body source could not be captured or parsed; "
             "AST-tier rules were skipped for this family")
    fix_hint = ("register a plain function (not a lambda/partial) so "
                "inspect.getsource sees it")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        if not fam.analysis.analyzable():
            yield self.finding(fam)


@register_rule
class UnfencedAsyncBody(FamilyRule):
    """Body never declares deliverables and family has no sync fence.

    On an async-dispatch backend (JAX) the timed loop then measures
    *enqueue* cost: calls return as soon as work is queued, the clock
    stops, and the device finishes afterwards, unobserved.
    """

    id = "SCOPE101"
    severity = "error"
    title = ("timed loop never calls state.deliver and the family "
             "declares no set_sync fence — on an async backend "
             "real_time measures dispatch enqueue, not the workload")
    fix_hint = ("declare the output with state.deliver(out) inside the "
                "loop, or mark the family host-synchronous with "
                "bench.set_sync(lambda ctx: None)")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        bench = fam.bench
        ana = fam.analysis
        if not ana.analyzable() or not ana.timed_loops:
            return
        if bench.use_manual_time or bench.sync_fn is not None:
            return
        if ana.calls_state_method("deliver"):
            return
        yield self.finding(fam)


@register_rule
class TimedRegionSetupWork(FamilyRule):
    """Allocation / jit construction inside the timed loop."""

    id = "SCOPE102"
    severity = "error"
    title = ""  # built per finding
    fix_hint = ("move allocation and jit/grad construction into a "
                "set_fixture(setup) — fixtures run untimed, and the "
                "warm phase reports compile time separately")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        if not fam.analysis.analyzable():
            return
        for call in fam.analysis.timed_region_calls():
            if call.name in TIMED_REGION_BANNED:
                yield self.finding(
                    fam,
                    message=(f"{call.name}() runs inside the timed loop "
                             f"(line {call.line}): allocation/compilation "
                             f"is billed to every measured iteration"))


@register_rule
class DeadParamAxis(FamilyRule):
    """A declared axis neither the body nor the fixture ever reads."""

    id = "SCOPE103"
    severity = "warning"
    title = ""
    fix_hint = ("drop the axis from the ParamSpace, or read it "
                "(state.params.<axis> in the body, params.<axis> in "
                "the fixture)")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        dead = fam.analysis.dead_axes()
        if not dead:
            return
        for axis in dead:
            yield self.finding(
                fam,
                message=(f"parameter axis {axis!r} is declared but never "
                         f"read by the body or fixture — every point "
                         f"along it re-measures the same workload"))


@register_rule
class NoThroughputCounters(FamilyRule):
    """No bytes/items/counters: the record is a bare time."""

    id = "SCOPE104"
    severity = "warning"
    title = ("body sets no throughput signal (set_bytes_processed / "
             "set_items_processed / state.counters) — records carry "
             "times but nothing to normalize them by, so cross-size "
             "comparisons and roofline columns stay empty")
    fix_hint = ("set bytes/items processed per iteration, or record a "
                "workload counter (state.counters[...] = ...)")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        ana = fam.analysis
        if not ana.analyzable() or not ana.timed_loops:
            return
        if ana.calls_state_method("set_bytes_processed") \
                or ana.calls_state_method("set_items_processed") \
                or ana.sets_counters():
            return
        yield self.finding(fam)


@register_rule
class WallClockInBody(FamilyRule):
    """Body reads a host clock — timing belongs to the meter stack."""

    id = "SCOPE105"
    severity = "error"
    title = ""
    fix_hint = ("delete the clock call; the wall/cpu meters own timing "
                "(a family that must time itself should declare "
                "manual_time() and use state.set_iteration_time)")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        if fam.bench.use_manual_time or not fam.analysis.analyzable():
            return
        for call in fam.analysis.body_calls():
            if call.name in WALL_CLOCK_CALLS:
                yield self.finding(
                    fam,
                    message=(f"{call.name}() called in the benchmark body "
                             f"(line {call.line}): bodies must not read "
                             f"host clocks — the meter stack owns timing"))


@register_rule
class ManualTimeNeverReported(FamilyRule):
    """manual_time() family that never calls set_iteration_time."""

    id = "SCOPE106"
    severity = "error"
    title = ("family declares manual_time() but the body never calls "
             "state.set_iteration_time — every record reports zero "
             "time, and cost hints derived from it schedule garbage")
    fix_hint = ("call state.set_iteration_time(seconds) inside the "
                "loop, or drop manual_time()")

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        if not fam.bench.use_manual_time or not fam.analysis.analyzable():
            return
        if not fam.analysis.calls_state_method("set_iteration_time"):
            yield self.finding(fam)


#: AST cache for SCOPE109's package-tree scan, keyed by file path →
#: (mtime, size, findings-data).  test suites run the linter dozens of
#: times per process; re-parsing the whole package each pass would
#: dominate the AST tier.
_HISTORY_OPEN_CACHE: dict = {}

#: Modules allowed to open history.jsonl directly: the store layer they
#: implement IS the sanctioned access path.
_HISTORY_OPEN_ALLOWED = ("core/history.py", "store/")


def _history_open_sites(path: str) -> list:
    """``(lineno, call text)`` for every ``open()`` whose argument
    subtree contains a ``history.jsonl`` string literal, cached by
    (mtime, size)."""
    try:
        st = os.stat(path)
        key = (st.st_mtime_ns, st.st_size)
    except OSError:
        return []
    cached = _HISTORY_OPEN_CACHE.get(path)
    if cached is not None and cached[0] == key:
        return cached[1]
    sites: list = []
    try:
        with open(path, "r", encoding="utf-8") as f:
            src = f.read()
        tree = ast.parse(src)
    except (OSError, SyntaxError, ValueError):
        _HISTORY_OPEN_CACHE[path] = (key, sites)
        return sites
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) \
                or dotted_name(node.func) != "open":
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            hit = any(isinstance(sub, ast.Constant)
                      and isinstance(sub.value, str)
                      and "history.jsonl" in sub.value
                      for sub in ast.walk(arg))
            if hit:
                sites.append((node.lineno,
                              ast.get_source_segment(src, node)
                              or "open(...)"))
                break
    _HISTORY_OPEN_CACHE[path] = (key, sites)
    return sites


@register_rule
class DirectHistoryOpen(RegistryRule):
    """``open("...history.jsonl")`` outside the sanctioned access layer.

    ``repro.core.history`` and ``repro.store`` are the only modules
    that may touch the history file directly: they own the corrupt-line
    skip semantics, the append protocol, and the store index's
    byte-offset watermark.  Any other call site re-opening the JSONL
    by hand bypasses all three — it crashes on the torn/garbage lines
    the sanctioned readers skip, and what it writes is invisible to the
    index until a rebuild.
    """

    id = "SCOPE109"
    severity = "warning"
    title = ""
    fix_hint = ("go through the store layer: repro.core.history "
                "(iter_lines/load_history/append_run) or repro.store "
                "(run_query/ingest_shards) — never open the JSONL "
                "directly")

    def check_registry(self, ctx: LintContext) -> Iterable[Finding]:
        import repro
        pkg_root = os.path.dirname(os.path.abspath(repro.__file__))
        for dirpath, dirnames, filenames in os.walk(pkg_root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__",)]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, pkg_root).replace(os.sep, "/")
                if rel.startswith(_HISTORY_OPEN_ALLOWED[1]) \
                        or rel == _HISTORY_OPEN_ALLOWED[0]:
                    continue
                for lineno, call in _history_open_sites(path):
                    yield self.finding(
                        family=f"module:repro/{rel}",
                        location=f"{path}:{lineno}",
                        message=(
                            f"{call} opens history.jsonl directly "
                            f"outside repro.core.history/repro.store — "
                            f"it bypasses the corrupt-line skip "
                            f"semantics and the store index watermark"))


#: Tunable-kernel entry points and their block-size knobs.  Call sites
#: that pin these to literal ints opt out of the searched defaults that
#: ``python -m repro tune`` ships (repro.kernels.tuning), so a refreshed
#: tuned.json never reaches them.  Keyed by the *last* dotted component
#: so aliased imports still match.
TUNED_KERNEL_KNOBS = {
    "matmul": ("bm", "bn", "bk"),
    "matmul_pallas": ("bm", "bn", "bk"),
    "pallas_matmul": ("bm", "bn", "bk"),
    "flash_attention": ("bq", "bk"),
    "flash_attention_pallas": ("bq", "bk"),
    "rmsnorm": ("br",),
    "rmsnorm_pallas": ("br",),
    "ssd": ("chunk",),
    "ssd_chunk_pallas": ("chunk",),
}


@register_rule
class HardcodedKernelBlocks(FamilyRule):
    """Kernel call site pins a block-size knob to a literal int."""

    id = "SCOPE107"
    severity = "warning"
    title = ""
    fix_hint = ("drop the literal so the call picks up the tuned "
                "defaults (repro.kernels.tuning: tuned.json, "
                "REPRO_TUNED_* env, builtin); refresh them with "
                "`python -m repro tune <family>`")

    def _funcs(self, fam: FamilyContext):
        ana = fam.analysis
        for func in (ana.body, ana.fixture):
            if func is not None:
                yield func

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        if not fam.analysis.analyzable():
            return
        for func in self._funcs(fam):
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                leaf = name.rsplit(".", 1)[-1]
                knobs = TUNED_KERNEL_KNOBS.get(leaf)
                if knobs is None:
                    continue
                for kw in node.keywords:
                    if kw.arg not in knobs:
                        continue
                    val = kw.value
                    if isinstance(val, ast.Constant) \
                            and type(val.value) is int:
                        yield self.finding(
                            fam,
                            message=(
                                f"{name}(..., {kw.arg}={val.value}) "
                                f"hardcodes a block size (line "
                                f"{kw.value.lineno}): literal knobs "
                                f"shadow the tuned defaults shipped by "
                                f"`python -m repro tune`"))


@register_rule
class HostClockInMeter(RegistryRule):
    """A registered meter's measurement methods read a host clock.

    Meters consume the timestamps the state and the sample payload
    provide (``state.elapsed``, ``state.cpu_elapsed``, per-sample
    ``latency_s``/``ttft_s`` fields stamped by the instrumented source).
    A meter that calls ``time.time()``/``perf_counter()`` in
    ``begin``/``observe``/``end`` re-measures *its own position in the
    call sequence*, not the event: on an async backend the method runs
    at dispatch-enqueue time, so the self-read clock reports enqueue —
    the exact un-fenced-timestamp bug class the serve engine's
    ``fence_timestamps`` and the wall meter's sync fence exist to fix.
    """

    id = "SCOPE108"
    severity = "error"
    title = ""
    fix_hint = ("read timestamps from the state (state.elapsed, "
                "state.cpu_elapsed) or from the sample payload the "
                "instrumented source stamped after fencing — never from "
                "a host clock inside the meter")

    #: The methods the stack drives around/inside the measured batch.
    METHODS = ("prepare", "begin", "observe", "end")

    def check_registry(self, ctx: LintContext) -> Iterable[Finding]:
        import inspect
        import textwrap

        from ..measure import METERS
        for name, factory in sorted(METERS.items()):
            cls = factory if isinstance(factory, type) else None
            if cls is None:
                try:
                    cls = type(factory())
                except Exception:  # noqa: BLE001 - unanalyzable factory
                    continue
            # own methods only: inherited Meter no-ops are clean by
            # definition, and scanning them would blame every meter
            # for one bad base class
            for meth in self.METHODS:
                fn = cls.__dict__.get(meth)
                if fn is None:
                    continue
                fn = inspect.unwrap(getattr(fn, "__func__", fn))
                try:
                    src = textwrap.dedent(inspect.getsource(fn))
                    tree = ast.parse(src)
                except (OSError, TypeError, SyntaxError):
                    continue
                loc = ""
                code = getattr(fn, "__code__", None)
                if code is not None:
                    loc = f"{code.co_filename}:{code.co_firstlineno}"
                for node in ast.walk(tree):
                    if not isinstance(node, ast.Call):
                        continue
                    called = dotted_name(node.func)
                    if called in WALL_CLOCK_CALLS:
                        yield self.finding(
                            family=f"meter:{name}",
                            location=loc,
                            message=(
                                f"meter {name!r} ({cls.__name__}."
                                f"{meth}) calls {called}(): meters "
                                f"must consume state/sample-provided "
                                f"timestamps, not read host clocks — "
                                f"a self-read clock stamps enqueue "
                                f"time under async dispatch"))


@register_rule
class MutableGlobalInBody(FamilyRule):
    """Body reads module-level mutable state the fingerprint can't see.

    The instance fingerprint (:mod:`repro.core.fingerprint`) hashes the
    body/fixture *source*, the kernel modules it imports, the params,
    the tuned artifact and the jax version — a module-level ``list`` /
    ``dict`` / ``set`` the body reads at call time is none of those.
    Mutate it between runs and two identical fingerprints time two
    different workloads, so ``repro ci`` happily skips an instance
    whose behavior changed.  Functions, classes, modules and constants
    are fine (their definitions live in hashed source); only mutable
    containers resolved from the body's globals — or an explicit
    ``global`` statement — are flagged.
    """

    id = "SCOPE110"
    severity = "warning"
    title = ""
    fix_hint = ("pass the value through the ParamSpace or build it in "
                "the fixture (both are fingerprinted); if it is truly "
                "constant, make it a scalar/tuple constant")

    #: Containers whose in-place mutation is invisible to source hashes.
    MUTABLE_TYPES = (list, dict, set, bytearray)

    @staticmethod
    def _local_names(func: ast.FunctionDef) -> set:
        """Names bound inside ``func`` — assignments, loop targets,
        comprehension vars, ``with ... as``, except aliases, args."""
        names = {a.arg for a in func.args.args + func.args.kwonlyargs}
        for extra in (func.args.vararg, func.args.kwarg):
            if extra is not None:
                names.add(extra.arg)
        for node in ast.walk(func):
            if isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store, ast.Del)):
                names.add(node.id)
            elif isinstance(node, (ast.FunctionDef,
                                   ast.AsyncFunctionDef, ast.ClassDef)):
                if node is not func:
                    names.add(node.name)
            elif isinstance(node, ast.ExceptHandler) and node.name:
                names.add(node.name)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for alias in node.names:
                    names.add((alias.asname
                               or alias.name.split(".")[0]))
        return names

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        ana = fam.analysis
        if not ana.analyzable():
            return
        body = ana.body
        fn_globals = getattr(fam.bench.fn, "__globals__", None)
        if fn_globals is None:
            return
        for node in ast.walk(body):
            if isinstance(node, ast.Global):
                yield self.finding(
                    fam,
                    message=(f"body declares `global "
                             f"{', '.join(node.names)}` (line "
                             f"{node.lineno}): state carried across "
                             f"iterations through module globals is "
                             f"invisible to the instance fingerprint, "
                             f"so delta runs (`repro ci`) can replay a "
                             f"changed workload as fresh"))
        locals_ = self._local_names(body)
        seen = set()
        for node in ast.walk(body):
            if not isinstance(node, ast.Name) \
                    or not isinstance(node.ctx, ast.Load) \
                    or node.id in locals_ or node.id in seen:
                continue
            if node.id not in fn_globals:
                continue
            value = fn_globals[node.id]
            if isinstance(value, self.MUTABLE_TYPES):
                seen.add(node.id)
                yield self.finding(
                    fam,
                    message=(f"body reads module-level "
                             f"{type(value).__name__} {node.id!r} "
                             f"(line {node.lineno}): mutable state "
                             f"outside the fingerprinted source — "
                             f"mutating it changes the measurement "
                             f"without changing the fingerprint, and "
                             f"delta runs (`repro ci`) will skip the "
                             f"instance as fresh"))
