"""Built-in trace-tier rules (SCOPE2xx): optimized-HLO hazards.

These rules compile the fixture's workload once (never running the
body) and read what XLA actually kept — the ``benchmark::DoNotOptimize``
class of bugs that no amount of source staring can find.
"""
from __future__ import annotations

from typing import Iterable

from .framework import FamilyContext, FamilyRule, Finding, LintContext, \
    register_rule


@register_rule
class WorkloadOptimizedAway(FamilyRule):
    """Optimized module has no compute instructions left."""

    id = "SCOPE201"
    severity = "error"
    title = ""
    fix_hint = ("make the output depend on the operands (not on "
                "trace-time constants) and deliver it — XLA cannot fold "
                "a computation whose inputs are runtime parameters and "
                "whose output escapes")
    requires_compile = True

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        out = fam.compiled
        if out is None or not out.analyzed():
            return
        if out.compute_ops == 0:
            ops = ", ".join(out.passive_only_ops) or "nothing"
            yield self.finding(
                fam,
                message=(f"workload for instance {out.instance!r} compiles "
                         f"to no compute instructions (optimized HLO "
                         f"contains only: {ops}) — XLA constant-folded or "
                         f"dead-code-eliminated the computation, so timings "
                         f"measure the copy path, not the op"))
        elif out.entry_params == 0 and out.operand_leaves > 0:
            yield self.finding(
                fam,
                message=(f"workload for instance {out.instance!r} takes no "
                         f"runtime parameters despite {out.operand_leaves} "
                         f"fixture operand(s) — the computation was folded "
                         f"at trace time and re-runs a precomputed result"))


@register_rule
class DeadOperand(FamilyRule):
    """Fixture operands the compiled entry never consumes."""

    id = "SCOPE202"
    severity = "warning"
    title = ""
    fix_hint = ("drop the unused operand from the fixture tuple, or fix "
                "the workload to actually consume it")
    requires_compile = True

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        out = fam.compiled
        if out is None or not out.analyzed():
            return
        if 0 < out.entry_params < out.operand_leaves:
            yield self.finding(
                fam,
                message=(f"fixture for instance {out.instance!r} supplies "
                         f"{out.operand_leaves} operand leaves but the "
                         f"compiled entry consumes only {out.entry_params} "
                         f"— the rest were dead-code-eliminated at trace "
                         f"time, so part of the declared workload is "
                         f"never measured"))


@register_rule
class OpaqueFixture(FamilyRule):
    """Fixture context does not follow ``(callable, *operands)``."""

    id = "SCOPE203"
    severity = "info"
    title = ("fixture context does not follow the (callable, *operands) "
             "convention — the compile tier and the cost-model meter "
             "cannot inspect this workload")
    fix_hint = ("return (jitted_fn, arg0, arg1, ...) from the fixture to "
                "opt into HLO-based checks and cost metrics")
    requires_compile = True

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        out = fam.compiled
        if out is None:
            return
        if not out.convention:
            yield self.finding(fam)
        elif out.error:
            yield self.finding(
                fam,
                message=(f"workload for instance {out.instance!r} could "
                         f"not be compiled for inspection: {out.error}"),
                fix_hint="")
