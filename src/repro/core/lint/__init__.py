"""``repro.core.lint`` — static analysis for benchmark hygiene.

``python -m repro lint`` reaches a verdict about every registered
family without executing a single timed repetition, through three
tiers of rules:

  * **AST** (SCOPE1xx): the body/fixture source, captured at
    registration — unfenced async dispatch, allocation inside the
    timed loop, dead parameter axes, missing throughput counters,
    wall-clock reads;
  * **trace** (SCOPE2xx): the fixture's workload lowered and compiled
    once — XLA constant-folding / dead-code elimination (the
    ``benchmark::DoNotOptimize`` class of bugs), dead operands;
  * **registry** (SCOPE3xx): cross-family consistency — instance-name
    collisions, sweeps that collapse onto duplicate points, empty
    scopes.

Rule catalog and authoring guide: docs/linting.md.
"""
from .analysis import FamilyAnalysis
from .compiled import CompiledWorkload, compile_workload
from .framework import (RULES, SEVERITIES, FamilyContext, FamilyRule,
                        Finding, LintContext, LintReport, RegistryRule,
                        Rule, parse_rules, register_rule, run_lint,
                        validate_rule_id)

# Importing the rule modules registers the built-in rules into RULES.
from . import rules_ast as _rules_ast  # noqa: F401,E402
from . import rules_registry as _rules_registry  # noqa: F401,E402
from . import rules_trace as _rules_trace  # noqa: F401,E402
from .cli import build_lint_parser, lint_main  # noqa: E402

__all__ = [
    "RULES", "SEVERITIES", "FamilyAnalysis", "FamilyContext", "FamilyRule",
    "Finding", "LintContext", "LintReport", "CompiledWorkload", "Rule",
    "RegistryRule", "build_lint_parser", "compile_workload", "lint_main",
    "parse_rules", "register_rule", "run_lint", "validate_rule_id",
]
