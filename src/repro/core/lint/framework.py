"""Rule framework for ``repro lint`` — findings, rules, registry, report.

The linter's contract is the inverse of the runner's: it must reach a
verdict about every registered benchmark family **without executing a
single timed repetition**.  Rules therefore see three progressively
deeper (and progressively more expensive) views of a family:

  * its *source* — the body and fixture functions captured at
    registration time (:mod:`repro.core.lint.analysis`, pure AST);
  * its *compiled workload* — the fixture's ``(jitted_fn, *operands)``
    lowered and compiled once per representative instance
    (:mod:`repro.core.lint.compiled`, optimized-HLO text only — the
    body itself is never called);
  * the *registry* — cross-family facts (instance-name collisions,
    empty sweeps) no single family can see.

A rule is a class with an id (``SCOPE101``-style), a severity, a title
and a fix hint, registered into :data:`RULES` with the
:func:`register_rule` decorator — the same shape as the meter registry
(:data:`repro.core.measure.METERS`), so scope authors ship custom rules
next to custom meters::

    from repro.core.lint import FamilyRule, register_rule

    @register_rule
    class NoGiantSweeps(FamilyRule):
        id = "MYSCOPE901"
        severity = "warning"
        title = "family sweeps more than 100 instances"
        fix_hint = "prune the ParamSpace with .where(...)"

        def check_family(self, ctx, fam):
            if len(fam.bench.instances()) > 100:
                yield self.finding(fam)

``run_lint`` drives every selected rule over a registry and returns a
:class:`LintReport` — text/JSON rendering and the severity gate used by
the CLI (``--strict`` promotes warnings to failures) live there.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence)

from ..logging import get_logger
from .analysis import FamilyAnalysis
from .compiled import CompiledWorkload, compile_workload

log = get_logger("lint")

#: Finding severities, most severe first.  ``error`` findings corrupt
#: measurements and gate by default; ``warning`` gates under
#: ``--strict``; ``info`` is advisory and never gates.
SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Finding:
    """One rule violation, attributed to a family (or a whole scope)."""

    rule: str                  # rule id, e.g. "SCOPE101"
    severity: str              # one of SEVERITIES
    scope: str                 # owning scope name ("" for registry-wide)
    family: str                # registered family name ("" for scope-wide)
    message: str               # what is wrong, in measurement terms
    fix_hint: str = ""         # how an author makes it go away
    location: str = ""         # "file:line" of the body, when known

    def target(self) -> str:
        return self.family or self.scope or "<registry>"

    def format(self) -> str:
        loc = f" [{self.location}]" if self.location else ""
        hint = f"\n      fix: {self.fix_hint}" if self.fix_hint else ""
        return (f"{self.target()}: {self.rule} {self.severity}: "
                f"{self.message}{loc}{hint}")

    def to_json(self) -> Dict[str, Any]:
        return {
            "rule": self.rule, "severity": self.severity,
            "scope": self.scope, "family": self.family,
            "message": self.message, "fix_hint": self.fix_hint,
            "location": self.location,
        }


class FamilyContext:
    """One family under analysis: the registered :class:`Benchmark` plus
    lazily-computed AST and compile-tier views shared by every rule (the
    AST is parsed once, the workload compiled once, however many rules
    read them)."""

    def __init__(self, bench, lint_ctx: "LintContext"):
        self.bench = bench
        self.scope = bench.scope
        self._ctx = lint_ctx
        self._analysis: Optional[FamilyAnalysis] = None
        self._compiled: Optional[CompiledWorkload] = None
        self._compiled_done = False

    @property
    def analysis(self) -> FamilyAnalysis:
        if self._analysis is None:
            self._analysis = FamilyAnalysis(self.bench)
        return self._analysis

    @property
    def compiled(self) -> Optional[CompiledWorkload]:
        """Compile-tier view; ``None`` when compile checks are disabled
        or the family has no fixture to lower."""
        if not self._ctx.compile_checks:
            return None
        if not self._compiled_done:
            self._compiled = compile_workload(self.bench)
            self._compiled_done = True
        return self._compiled

    def location(self) -> str:
        b = self.bench
        if b.source_file and b.source_line:
            return f"{b.source_file}:{b.source_line}"
        return ""


class LintContext:
    """Everything a rule may inspect: the family contexts, the scope
    names under analysis, and the compile-tier switch."""

    def __init__(self, benches: Sequence[Any],
                 scope_names: Optional[Sequence[str]] = None,
                 compile_checks: bool = True):
        self.families = [FamilyContext(b, self) for b in benches]
        self.scope_names = list(scope_names) if scope_names is not None \
            else sorted({b.scope for b in benches})
        self.compile_checks = compile_checks


class Rule:
    """Base rule: identity + metadata.  Subclass :class:`FamilyRule` for
    per-family checks or :class:`RegistryRule` for cross-family ones."""

    id: str = ""
    severity: str = "warning"
    title: str = ""
    fix_hint: str = ""
    #: Rules that lower/compile the workload are skipped by --no-compile.
    requires_compile: bool = False

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, fam: Optional[FamilyContext] = None, *,
                message: str = "", scope: str = "", family: str = "",
                fix_hint: Optional[str] = None,
                location: Optional[str] = None) -> Finding:
        """Build a finding with this rule's id/severity and the family's
        attribution filled in; ``message`` defaults to the rule title."""
        if fam is not None:
            scope = scope or fam.scope
            family = family or fam.bench.name
            if location is None:
                location = fam.location()
        return Finding(
            rule=self.id, severity=self.severity, scope=scope,
            family=family, message=message or self.title,
            fix_hint=self.fix_hint if fix_hint is None else fix_hint,
            location=location or "",
        )


class FamilyRule(Rule):
    """A rule evaluated independently against every family."""

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        for fam in ctx.families:
            yield from self.check_family(ctx, fam)

    def check_family(self, ctx: LintContext,
                     fam: FamilyContext) -> Iterable[Finding]:
        return ()


class RegistryRule(Rule):
    """A rule evaluated once over the whole registry."""

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        yield from self.check_registry(ctx)

    def check_registry(self, ctx: LintContext) -> Iterable[Finding]:
        return ()


#: Built-in + custom rule registry: rule id → rule factory (the meter
#: registry pattern — repro.core.measure.METERS).
RULES: Dict[str, Callable[[], Rule]] = {}


def register_rule(cls: Callable[[], Rule]) -> Callable[[], Rule]:
    """Class decorator adding a rule to :data:`RULES` (keyed by id)."""
    rule_id = getattr(cls, "id", "")
    if not rule_id:
        raise ValueError(f"rule {cls!r} declares no id")
    if getattr(cls, "severity", None) not in SEVERITIES:
        raise ValueError(f"rule {rule_id}: severity must be one of "
                         f"{', '.join(SEVERITIES)}")
    if rule_id in RULES:
        raise ValueError(f"rule id {rule_id!r} already registered")
    RULES[rule_id] = cls
    return cls


def validate_rule_id(rule_id: str) -> str:
    """Raise ``ValueError`` (with the available set) unless registered —
    the single check behind ``--rules`` (mirrors validate_meter_name)."""
    if rule_id not in RULES:
        raise ValueError(f"unknown rule {rule_id!r} "
                         f"(available: {', '.join(sorted(RULES))})")
    return rule_id


def parse_rules(spec: str) -> List[str]:
    """``--rules SCOPE101,SCOPE201`` → validated id list."""
    ids: List[str] = []
    for part in spec.split(","):
        rule_id = part.strip()
        if not rule_id:
            continue
        validate_rule_id(rule_id)
        if rule_id not in ids:
            ids.append(rule_id)
    if not ids:
        raise ValueError("--rules needs at least one rule id")
    return ids


@dataclass
class LintReport:
    """The outcome of one lint pass: findings + what was analyzed."""

    findings: List[Finding] = field(default_factory=list)
    families_checked: int = 0
    scopes_checked: int = 0
    rules_run: List[str] = field(default_factory=list)

    def counts(self) -> Dict[str, int]:
        out = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            out[f.severity] = out.get(f.severity, 0) + 1
        return out

    def by_severity(self, severity: str) -> List[Finding]:
        return [f for f in self.findings if f.severity == severity]

    def failed(self, strict: bool = False) -> bool:
        """The CLI gate: errors always fail; --strict fails warnings too."""
        counts = self.counts()
        if counts["error"]:
            return True
        return strict and counts["warning"] > 0

    def summary(self) -> str:
        c = self.counts()
        return (f"checked {self.families_checked} families across "
                f"{self.scopes_checked} scopes with "
                f"{len(self.rules_run)} rules: "
                f"{c['error']} error(s), {c['warning']} warning(s), "
                f"{c['info']} info")

    def format_text(self) -> str:
        lines: List[str] = []
        rank = {s: i for i, s in enumerate(SEVERITIES)}
        ordered = sorted(self.findings,
                         key=lambda f: (rank[f.severity], f.scope,
                                        f.family, f.rule))
        for f in ordered:
            lines.append(f.format())
        if lines:
            lines.append("")
        lines.append(self.summary())
        return "\n".join(lines)

    def to_json(self) -> Dict[str, Any]:
        return {
            "version": 1,
            "families_checked": self.families_checked,
            "scopes_checked": self.scopes_checked,
            "rules_run": list(self.rules_run),
            "counts": self.counts(),
            "findings": [f.to_json() for f in self.findings],
        }


def run_lint(benches: Sequence[Any],
             scope_names: Optional[Sequence[str]] = None,
             rules: Optional[Sequence[str]] = None,
             compile_checks: bool = True) -> LintReport:
    """Run lint rules over registered benchmark families.

    ``benches`` is a list of :class:`~repro.core.benchmark.Benchmark`
    (usually ``REGISTRY.filter(...)``); ``scope_names`` the scopes under
    analysis (for the zero-instance rule — defaults to the scopes the
    families belong to); ``rules`` a subset of :data:`RULES` ids (all
    when omitted); ``compile_checks=False`` skips the rules that lower
    and compile fixtures (the AST and registry tiers still run).

    Nothing here executes a benchmark body or starts a timer: analysis
    is source + (optionally) compile-only.
    """
    ctx = LintContext(benches, scope_names, compile_checks)
    selected = list(rules) if rules else sorted(RULES)
    findings: List[Finding] = []
    ran: List[str] = []
    for rule_id in selected:
        rule = RULES[validate_rule_id(rule_id)]()
        if rule.requires_compile and not compile_checks:
            continue
        ran.append(rule_id)
        try:
            findings.extend(rule.run(ctx))
        except Exception as e:  # noqa: BLE001 - a broken rule must not
            # take down the whole pass (mirrors scope import isolation)
            log.warning("rule %s crashed: %r", rule_id, e)
    return LintReport(findings=findings,
                      families_checked=len(ctx.families),
                      scopes_checked=len(ctx.scope_names),
                      rules_run=ran)
