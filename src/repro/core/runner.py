"""Benchmark runner + Google-Benchmark-compatible JSON writer.

Reimplements the run stage of the SCOPE binary (paper Fig. 2(d)):

  * fixture phase — a family's ``setup(params) -> ctx`` runs once per
    instance, *untimed*, before anything is measured, so array
    allocation and ``jax.jit`` construction never pollute the numbers;
  * measurement — a pluggable :class:`~repro.core.measure.MeterStack`
    is driven around every warm, calibration and repetition batch
    (``begin(state)`` / ``end(state) -> {metric: value}``): the wall
    meter fences async dispatch before the clock stops, the CPU meter
    makes ``cpu_time`` a real ``process_time`` measurement instead of a
    copy of ``real_time``, and opt-in meters (``--meters costmodel``)
    contribute extra metrics that land as GB counters on every record;
  * warm phase — the first call of the body is measured separately and
    emitted as ``compile_time_s`` per instance: on a jax/pallas system
    the first warm call is where tracing + XLA compilation happen, and
    the compile-vs-steady-state split is a first-class measurement;
  * adaptive iteration counts — a batch of iterations grows
    geometrically until measured wall time exceeds ``min_time``
    (Google Benchmark's algorithm), calibrated on *post-warm* batches
    so compile time can't distort the batch size;
  * repetitions with mean/median/stddev aggregate records;
  * results serialized in the Google Benchmark JSON schema (``context``
    + ``benchmarks[]``), counters inlined per record — the property
    that makes ScopePlot "compatible with other tools that use that
    library".  Counters that would shadow a canonical GB key
    (``real_time``, ``iterations``, ...) are renamed
    ``counter_<name>`` instead of silently corrupting the record;
  * two execution granularities: :func:`run_benchmarks` sweeps whole
    families (honoring ``RunOptions.param_filter``, the ``--param
    key=value`` selection), :func:`run_single_instance` runs exactly
    one named instance — the unit the plan-grained orchestrator
    (repro.core.plan) schedules.
"""
from __future__ import annotations

import json
import math
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .benchmark import (Benchmark, Params, State, TIME_UNITS, match_params)
from .logging import get_logger
from .measure import CPU_TIME, MeterStack, WALL_TIME
from .sysinfo import build_context

log = get_logger("runner")

#: Canonical GB record keys — counters may not shadow these (a counter
#: named ``real_time`` would silently overwrite the measurement).
RESERVED_RECORD_KEYS = frozenset({
    "name", "run_name", "run_type", "repetitions", "repetition_index",
    "threads", "iterations", "real_time", "cpu_time", "time_unit",
    "aggregate_name", "bytes_per_second", "items_per_second", "label",
    "error_occurred", "error_message", "skipped", "skip_message",
    "compile_time_s",
})


@dataclass
class RunOptions:
    min_time: float = 0.05          # seconds of measured time per instance
    repetitions: int = 1
    max_iterations: int = 1 << 22   # safety valve
    report_aggregates_only: bool = False
    # --param key=value selection: axis name → accepted string values
    param_filter: Optional[Dict[str, List[str]]] = None
    # --meters selection: measure.METERS names driven around every batch
    # (None → measure.DEFAULT_METERS); a family's set_meters() wins.
    # Plain strings so the options survive the JSON round-trip to
    # subprocess workers at both shard grains.
    meters: Optional[List[str]] = None
    # --slo-ms: latency objective the latency meter judges goodput
    # against (milliseconds; None → every completed request is good)
    slo_ms: Optional[float] = None


@dataclass
class RunRecord:
    """One row of the ``benchmarks`` array in the output JSON."""
    name: str
    run_name: str
    run_type: str                  # "iteration" | "aggregate"
    iterations: int
    real_time: float               # in time_unit
    cpu_time: float
    time_unit: str
    repetitions: int = 1
    repetition_index: int = 0
    threads: int = 1
    aggregate_name: Optional[str] = None
    bytes_per_second: Optional[float] = None
    items_per_second: Optional[float] = None
    label: Optional[str] = None
    error_occurred: bool = False
    error_message: Optional[str] = None
    skipped: bool = False
    skip_message: Optional[str] = None
    compile_time_s: Optional[float] = None   # warm-phase first-call time
    counters: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "run_name": self.run_name,
            "run_type": self.run_type,
            "repetitions": self.repetitions,
            "repetition_index": self.repetition_index,
            "threads": self.threads,
            "iterations": self.iterations,
            "real_time": self.real_time,
            "cpu_time": self.cpu_time,
            "time_unit": self.time_unit,
        }
        if self.aggregate_name:
            d["aggregate_name"] = self.aggregate_name
        if self.bytes_per_second is not None:
            d["bytes_per_second"] = self.bytes_per_second
        if self.items_per_second is not None:
            d["items_per_second"] = self.items_per_second
        if self.label:
            d["label"] = self.label
        if self.error_occurred:
            d["error_occurred"] = True
            d["error_message"] = self.error_message
        if self.skipped:
            d["skipped"] = True
            d["skip_message"] = self.skip_message
        if self.compile_time_s is not None:
            d["compile_time_s"] = self.compile_time_s
        # GB inlines counters at top level; a counter shadowing a
        # canonical key is renamed, never allowed to overwrite it
        for key, value in self.counters.items():
            if key in RESERVED_RECORD_KEYS:
                log.warning("benchmark %s: counter %r shadows a canonical "
                            "record key; renamed to %r", self.name, key,
                            f"counter_{key}")
                key = f"counter_{key}"
            d[key] = value
        return d


def _as_params(bench: Benchmark, point) -> Params:
    """Normalize a caller-supplied instance point to Params (accepts a
    legacy int tuple for back-compat)."""
    if isinstance(point, Params):
        return point
    return bench._legacy_params(tuple(point))


def _run_batch(bench: Benchmark, params: Params, n: int,
               fixture: Any, stack: MeterStack
               ) -> Tuple[State, Dict[str, float]]:
    """One measured batch: the meter stack brackets the body."""
    state = State(max_iterations=n, params=params, fixture=fixture)
    stack.begin(state)
    bench.fn(state)
    return state, stack.end(state)


def run_instance(bench: Benchmark, point, opts: RunOptions
                 ) -> List[RunRecord]:
    """Run one (family × params) instance: fixture, warm, calibrate,
    repeat, aggregate.  Every batch is measured through the instance's
    :class:`~repro.core.measure.MeterStack` (family ``set_meters``
    override, else ``opts.meters``, else the default wall+cpu set)."""
    params = _as_params(bench, point)
    name = bench.instance_name(params if bench.space is not None
                               else tuple(params.values()))
    min_time = bench.min_time if bench.min_time is not None else opts.min_time
    reps = bench.repetitions if bench.repetitions is not None else opts.repetitions
    unit_scale = TIME_UNITS[bench.unit]
    stack = MeterStack.build(bench.meters if bench.meters is not None
                             else opts.meters, bench, run_opts=opts)

    # -- fixture: setup(params) -> ctx, untimed --------------------------
    fixture = None
    if bench.fixture is not None:
        try:
            fixture = bench.fixture(params)
        except Exception as e:  # noqa: BLE001 - isolate fixture failures
            st = State(params=params)
            st.skip_with_error(f"fixture failed: {e!r}")
            return [_error_record(bench, name, st, reps)]

    # -- meter prepare: one-time analysis, before anything is timed ----
    stack.prepare(State(params=params, fixture=fixture))

    # -- warm phase: first call measured separately ----------------------
    # On jax the first call traces + compiles; its wall time is the
    # compile_time_s record.  The warm batch never feeds calibration.
    # Whole-batch wall (not the meter's loop window) so trace work
    # outside the timed loop still counts, with the meter's fence
    # guaranteeing the compiled work finished before the clock stops.
    t0 = time.perf_counter()
    warm, _ = _run_batch(bench, params, 1, fixture, stack)
    compile_s = time.perf_counter() - t0
    if warm.error_occurred or warm.skipped:
        return [_error_record(bench, name, warm, reps)]

    # -- calibration: grow n until measured time >= min_time -----------
    if bench.iterations is not None:
        n = bench.iterations
    else:
        n = 1
        while True:
            cal, cal_metrics = _run_batch(bench, params, n, fixture, stack)
            if cal.error_occurred or cal.skipped:
                return [_error_record(bench, name, cal, reps)]
            t = cal_metrics.get(WALL_TIME, 0.0)
            if t >= min_time or n >= opts.max_iterations:
                break
            if t <= 0:
                n = min(n * 10, opts.max_iterations)
            else:
                # GB's multiplier: overshoot slightly to converge fast
                mult = min(10.0, max(2.0, 1.4 * min_time / t))
                n = min(int(math.ceil(n * mult)), opts.max_iterations)

    # -- timed repetitions ------------------------------------------------
    records: List[RunRecord] = []
    per_iter_times: List[float] = []
    rep_values: Dict[str, List[float]] = {}   # per-rep series → aggregates

    def _track(key: str, value: Optional[float]) -> None:
        if value is not None:
            rep_values.setdefault(key, []).append(value)

    for rep in range(reps):
        st, metrics = _run_batch(bench, params, n, fixture, stack)
        if st.error_occurred or st.skipped:
            records.append(_error_record(bench, name, st, reps, rep))
            continue
        total = metrics.get(WALL_TIME, 0.0)
        iters = max(st.iterations, 1)
        per_iter = total / iters
        per_iter_times.append(per_iter)
        # cpu_time: a real measurement when the CPU meter ran; bodies
        # without one fall back to wall (the pre-meter behaviour)
        cpu_per_iter = metrics[CPU_TIME] / iters if CPU_TIME in metrics \
            else per_iter
        _track("cpu_time", cpu_per_iter)
        # meter metrics beyond the canonical times land as counters;
        # the body's own counters win on a name collision
        counters = {k: v for k, v in metrics.items()
                    if k not in (WALL_TIME, CPU_TIME)}
        counters.update(st.counters)
        for key, value in counters.items():
            _track(key, value)
        rec = RunRecord(
            name=name, run_name=name, run_type="iteration",
            iterations=st.iterations,
            real_time=per_iter * unit_scale,
            cpu_time=cpu_per_iter * unit_scale,
            time_unit=bench.unit,
            repetitions=reps, repetition_index=rep,
            label=st.label or None,
            compile_time_s=compile_s,
            counters=counters,
        )
        if st.bytes_processed:
            rec.bytes_per_second = st.bytes_processed * st.iterations / total
            _track("bytes_per_second", rec.bytes_per_second)
        if st.items_processed:
            rec.items_per_second = st.items_processed * st.iterations / total
            _track("items_per_second", rec.items_per_second)
        records.append(rec)

    # -- aggregates ---------------------------------------------------
    # Each aggregate applies its statistic uniformly: to the times, to
    # cpu_time, to the throughput rates, and to every counter present
    # in all repetitions — so --report-aggregates-only keeps the full
    # measurement surface, not just the wall clock.
    if reps > 1 and len(per_iter_times) > 1:
        aggs = {
            "mean": statistics.fmean,
            "median": statistics.median,
            "stddev": statistics.stdev,
        }
        full_series = {k: v for k, v in rep_values.items()
                       if len(v) == len(per_iter_times)}
        for agg_name, agg_fn in aggs.items():
            cpu_series = full_series.get("cpu_time")
            rec = RunRecord(
                name=f"{name}_{agg_name}", run_name=name,
                run_type="aggregate", aggregate_name=agg_name,
                iterations=n,
                real_time=agg_fn(per_iter_times) * unit_scale,
                cpu_time=(agg_fn(cpu_series) if cpu_series
                          else agg_fn(per_iter_times)) * unit_scale,
                # the count the statistics are over: errored repetitions
                # contribute no sample, and consumers reconstructing n
                # from an aggregates-only document must not over-trust
                # a stddev backed by fewer samples than requested
                time_unit=bench.unit, repetitions=len(per_iter_times),
                compile_time_s=compile_s if agg_name != "stddev" else None,
                counters={k: agg_fn(v) for k, v in full_series.items()
                          if k not in ("cpu_time", "bytes_per_second",
                                       "items_per_second")},
            )
            bps = full_series.get("bytes_per_second")
            ips = full_series.get("items_per_second")
            if bps:
                rec.bytes_per_second = agg_fn(bps)
            if ips:
                rec.items_per_second = agg_fn(ips)
            records.append(rec)
        if opts.report_aggregates_only:
            records = [r for r in records if r.run_type == "aggregate"]
    return records


def _error_record(bench: Benchmark, name: str, st: State, reps: int,
                  rep: int = 0) -> RunRecord:
    return RunRecord(
        name=name, run_name=name, run_type="iteration",
        iterations=st.iterations, real_time=0.0, cpu_time=0.0,
        time_unit=bench.unit, repetitions=reps, repetition_index=rep,
        error_occurred=st.error_occurred, error_message=st.error_message or None,
        skipped=st.skipped, skip_message=st.skip_message or None,
    )


def run_single_instance(benches: Sequence[Benchmark], instance_name: str,
                        opts: Optional[RunOptions] = None,
                        context_extra: Optional[Dict[str, Any]] = None
                        ) -> Dict[str, Any]:
    """Run exactly one *named* instance; return a full GB-JSON document.

    The plan-grained orchestrator's unit of work (repro.core.plan):
    ``instance_name`` is a Google-Benchmark display name
    (``scope/family/axis:value/...``), matched against every instance
    of ``benches``.  Crashes degrade to an error record, like
    :func:`run_benchmarks`; an unknown name raises ``KeyError`` so the
    caller can tell "no such instance" apart from "instance failed".
    """
    opts = opts or RunOptions()
    for bench in benches:
        for name, params in bench.instances():
            if name != instance_name:
                continue
            try:
                records = run_instance(bench, params, opts)
            except Exception as e:  # noqa: BLE001 - isolate benchmark crashes
                log.error("benchmark %s crashed: %s", name, e)
                st = State()
                st.skip_with_error(f"crashed: {e}")
                records = [_error_record(bench, name, st, 1)]
            return {
                "context": build_context(context_extra),
                "benchmarks": [r.to_json() for r in records],
            }
    raise KeyError(f"no benchmark instance named {instance_name!r}")


def run_benchmarks(benches: Sequence[Benchmark],
                   opts: Optional[RunOptions] = None,
                   context_extra: Optional[Dict[str, Any]] = None,
                   progress: bool = True) -> Dict[str, Any]:
    """Run benchmark families; return the full GB-JSON document as a dict.

    Instances not matching ``opts.param_filter`` (the ``--param``
    selection) are skipped without a record — selection, not failure.
    """
    opts = opts or RunOptions()
    all_records: List[RunRecord] = []
    t0 = time.perf_counter()
    for bench in benches:
        for name, params in bench.instances():
            if not match_params(params, opts.param_filter):
                continue
            if progress:
                log.info("running %s", name)
            try:
                all_records.extend(run_instance(bench, params, opts))
            except Exception as e:  # noqa: BLE001 - isolate benchmark crashes
                log.error("benchmark %s crashed: %s", name, e)
                st = State()
                st.skip_with_error(f"crashed: {e}")
                all_records.append(_error_record(bench, name, st, 1))
    elapsed = time.perf_counter() - t0
    log.info("ran %d records in %.2fs", len(all_records), elapsed)
    return {
        "context": build_context(context_extra),
        "benchmarks": [r.to_json() for r in all_records],
    }


def write_json(doc: Dict[str, Any], path_or_file) -> None:
    if hasattr(path_or_file, "write"):
        json.dump(doc, path_or_file, indent=2)
    else:
        with open(path_or_file, "w") as f:
            json.dump(doc, f, indent=2)
