"""Leveled, scope-tagged logging — the spdlog analogue from paper §III-E.

Every scope gets a named logger so output is attributable ("consistent output
mechanism").  Kept deliberately tiny: stdlib logging with one shared handler,
a compact format, and an env/flag-controlled level.
"""
from __future__ import annotations

import logging as _pylogging
import os
import sys
import time

_FORMAT = "[%(asctime)s.%(msecs)03d] [%(name)s] [%(levelname)s] %(message)s"
_DATEFMT = "%H:%M:%S"

_configured = False


def _configure() -> None:
    global _configured
    if _configured:
        return
    handler = _pylogging.StreamHandler(sys.stderr)
    handler.setFormatter(_pylogging.Formatter(_FORMAT, datefmt=_DATEFMT))
    root = _pylogging.getLogger("scope")
    root.addHandler(handler)
    root.propagate = False
    level = os.environ.get("SCOPE_LOG_LEVEL", "INFO").upper()
    root.setLevel(getattr(_pylogging, level, _pylogging.INFO))
    _configured = True


def get_logger(name: str) -> _pylogging.Logger:
    """Return a logger tagged ``scope/<name>`` (one per scope, typically)."""
    _configure()
    return _pylogging.getLogger(f"scope.{name}")


def set_level(level: str) -> None:
    _configure()
    _pylogging.getLogger("scope").setLevel(
        getattr(_pylogging, level.upper(), _pylogging.INFO)
    )


class Timer:
    """Context manager used by benchmark bodies for coarse phase timing."""

    def __init__(self) -> None:
        self.elapsed = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self._t0
