"""Scope plugin abstraction — paper §IV (Design of Scope Submodules).

A *scope* is an independently-developed group of benchmarks.  In the paper,
scopes are Git submodules exporting CMake object libraries, conditionally
compiled into the SCOPE binary (``-DENABLE_EXAMPLE=ON``).  Here, a scope is a
subpackage exporting a :class:`Scope` object; discovery imports are lazy and
failure-isolated, and enable/disable happens at run-configure time —
preserving the three design goals:

  * extensibility — new scopes need only define a Scope and call
    ``register_benchmark``; nothing in core enumerates them by name
    (external packages can register via ``add_scope``);
  * portability — a scope whose imports fail (missing optional dependency)
    is marked unavailable rather than breaking the binary;
  * development silos — scopes never import each other; shared code lives
    only in ``repro.core``.

The manager stops at configuration: it loads, enables/disables, and
registers scopes, then hands off.  *Scheduling* is the work-plan layer's
job — :func:`repro.core.plan.build_plan` enumerates a configured manager's
registry into addressable benchmark instances and
:func:`repro.core.plan.scope_worklist` derives the scope-grained work list;
the orchestrator consumes whichever granularity ``--shard-grain`` selects.
"""
from __future__ import annotations

import importlib
import traceback
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .flags import FLAGS, FlagRegistry
from .hooks import HOOKS, HookChain
from .logging import get_logger
from .registry import REGISTRY, BenchmarkRegistry

log = get_logger("scope")

# Scopes bundled with the binary — the Table IV analogue.  External scopes
# are added with add_scope(); nothing else in core knows this list.
BUILTIN_SCOPES = [
    "repro.scopes.example_scope",
    "repro.scopes.mxu_scope",
    "repro.scopes.comm_scope",
    "repro.scopes.nn_scope",
    "repro.scopes.instr_scope",
    "repro.scopes.histo_scope",
    "repro.scopes.linalg_scope",
    "repro.scopes.io_scope",
    "repro.scopes.model_scope",
    "repro.scopes.serve_scope",
]


@dataclass
class Scope:
    """One benchmark group: metadata + registration/initialization hooks."""

    name: str
    version: str = "1.0.0"
    description: str = ""
    # register(registry): add Benchmark objects.  Called when enabled.
    register: Optional[Callable[[BenchmarkRegistry], None]] = None
    # declare_flags(flags): add CLI options (clara::Opts analogue).
    declare_flags: Optional[Callable[[FlagRegistry], None]] = None
    # init hooks (paper §III-G), run before benchmarks execute.
    pre_parse: Optional[Callable[[], Optional[int]]] = None
    post_parse: Optional[Callable[[], Optional[int]]] = None
    required: List[str] = field(default_factory=list)   # python deps


@dataclass
class _LoadedScope:
    scope: Scope
    module: str
    enabled: bool = True
    available: bool = True
    error: str = ""


class ScopeManager:
    """Configure stage (paper Fig. 2(b)): load, enable/disable, register."""

    def __init__(self, registry: BenchmarkRegistry = REGISTRY,
                 flags: FlagRegistry = FLAGS, hooks: HookChain = HOOKS):
        self.registry = registry
        self.flags = flags
        self.hooks = hooks
        self._scopes: Dict[str, _LoadedScope] = {}

    # -- discovery ------------------------------------------------------
    def load(self, modules: Optional[List[str]] = None) -> None:
        """Import scope modules; each must export ``SCOPE: Scope``."""
        for modname in modules if modules is not None else BUILTIN_SCOPES:
            if modname in {s.module for s in self._scopes.values()}:
                continue
            try:
                mod = importlib.import_module(modname)
                scope: Scope = getattr(mod, "SCOPE")
                self.add_scope(scope, module=modname)
            except Exception:  # noqa: BLE001 - isolation requirement
                short = modname.rsplit(".", 1)[-1]
                self._scopes[short] = _LoadedScope(
                    scope=Scope(name=short), module=modname,
                    enabled=False, available=False,
                    error=traceback.format_exc(limit=2),
                )
                log.warning("scope %s unavailable (import failed)", short)

    def add_scope(self, scope: Scope, module: str = "<external>") -> None:
        """Register an externally-constructed scope (no central list)."""
        if scope.name in self._scopes:
            raise ValueError(f"scope {scope.name!r} already loaded")
        self._scopes[scope.name] = _LoadedScope(scope=scope, module=module)
        if scope.declare_flags:
            scope.declare_flags(self.flags)
        if scope.pre_parse:
            self.hooks.register_pre_parse(scope.pre_parse, owner=scope.name)
        if scope.post_parse:
            self.hooks.register_post_parse(scope.post_parse, owner=scope.name)

    # -- enable/disable (the -DENABLE_X=ON analogue) --------------------
    def set_enabled(self, name: str, enabled: bool) -> None:
        if name not in self._scopes:
            raise KeyError(f"unknown scope {name!r}; have "
                           f"{sorted(self._scopes)}")
        self._scopes[name].enabled = enabled

    def configure(self, enable: Optional[List[str]] = None,
                  disable: Optional[List[str]] = None) -> None:
        if enable:
            only = set(enable)
            known = only & set(self._scopes)
            unknown = only - known
            if unknown:
                log.warning("--enable-scope names no loaded scope: %s "
                            "(have %s)", sorted(unknown),
                            sorted(self._scopes))
            if known:
                for s in self._scopes.values():
                    s.enabled = s.scope.name in known
            else:
                # every name was unknown — a typo must not silently
                # disable the whole binary; leave the selection unchanged
                log.warning("--enable-scope selected nothing; scope "
                            "enablement left unchanged")
        for name in disable or []:
            self.set_enabled(name, False)

    # -- build stage: register enabled scopes' benchmarks ----------------
    def register_all(self) -> None:
        for s in self._scopes.values():
            if not (s.enabled and s.available and s.scope.register):
                continue
            try:
                s.scope.register(self.registry)
            except Exception:  # noqa: BLE001
                s.available = False
                s.error = traceback.format_exc(limit=2)
                self.registry.remove_scope(s.scope.name)
                log.warning("scope %s registration failed", s.scope.name)

    # -- introspection ------------------------------------------------
    def scopes(self) -> List[_LoadedScope]:
        return list(self._scopes.values())

    def status(self) -> Dict[str, str]:
        return {
            s.scope.name: ("enabled" if s.enabled and s.available else
                           "disabled" if s.available else "unavailable")
            for s in self._scopes.values()
        }
