"""``python -m repro`` — the SCOPE binary."""
import sys

from repro.core.main import main

if __name__ == "__main__":
    sys.exit(main())
