"""Object model for Google-Benchmark JSON files (paper §V-A.6).

ScopePlot "has an object model for JSON files and various methods for
filtering them and converting them to pandas DataFrames".  We mirror that:
:class:`BenchmarkFile` wraps a document, records are :class:`BenchmarkRecord`
views, and conversions target :class:`repro.scopeplot.frame.Frame` (a small
columnar table; pandas is not available offline).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Iterator, List, Optional, Union

from .frame import Frame

_STANDARD_FIELDS = {
    "name", "run_name", "run_type", "repetitions", "repetition_index",
    "threads", "iterations", "real_time", "cpu_time", "time_unit",
    "aggregate_name", "bytes_per_second", "items_per_second", "label",
    "error_occurred", "error_message", "skipped", "skip_message",
    "compile_time_s",
}


@dataclass
class BenchmarkRecord:
    """One entry of the ``benchmarks`` array."""
    raw: Dict[str, Any]

    @property
    def name(self) -> str:
        return self.raw.get("name", "")

    @property
    def scope(self) -> str:
        """Owning scope, from the ``<scope>/<family>`` name prefix."""
        return self.name.split("/", 1)[0] if "/" in self.name else ""

    @property
    def real_time(self) -> Optional[float]:
        return self.raw.get("real_time")

    @property
    def time_unit(self) -> str:
        return self.raw.get("time_unit", "ns")

    def real_time_seconds(self) -> Optional[float]:
        scale = {"ns": 1e-9, "us": 1e-6, "ms": 1e-3, "s": 1.0}
        t = self.real_time
        return None if t is None else t * scale.get(self.time_unit, 1.0)

    @property
    def counters(self) -> Dict[str, Any]:
        return {k: v for k, v in self.raw.items()
                if k not in _STANDARD_FIELDS}

    def get(self, key: str, default: Any = None) -> Any:
        return self.raw.get(key, default)

    def args(self) -> List[str]:
        """Arg components parsed back out of the GB name.

        Components that are ``name:value`` or pure numbers; leading
        scope/family path components are skipped.
        """
        out = []
        for part in self.name.split("/")[1:]:
            if ":" in part or part.replace(".", "", 1).isdigit():
                out.append(part)
        return out

    @property
    def params(self) -> Dict[str, str]:
        """Typed parameters parsed back out of the instance name: every
        ``axis:value`` component as a string-valued mapping.

        Parsed from ``run_name`` (falling back to ``name``) so aggregate
        records — whose display name carries a ``_mean``/``_stddev``
        suffix — resolve to their instance's parameters, not to a
        corrupted trailing axis value.
        """
        from repro.core.benchmark import name_params
        return name_params(self.raw.get("run_name") or self.name)

    def arg(self, key_or_index: Union[str, int]) -> Optional[str]:
        parts = self.args()
        if isinstance(key_or_index, int):
            return parts[key_or_index] if key_or_index < len(parts) else None
        for p in parts:
            if p.startswith(key_or_index + ":"):
                return p.split(":", 1)[1]
        return None


@dataclass
class BenchmarkFile:
    """A whole GB-JSON document: ``context`` + ``benchmarks``."""
    context: Dict[str, Any] = field(default_factory=dict)
    records: List[BenchmarkRecord] = field(default_factory=list)

    # -- I/O ------------------------------------------------------------
    @classmethod
    def from_dict(cls, doc: Dict[str, Any]) -> "BenchmarkFile":
        return cls(context=doc.get("context", {}),
                   records=[BenchmarkRecord(b)
                            for b in doc.get("benchmarks", [])])

    def to_dict(self) -> Dict[str, Any]:
        return {"context": self.context,
                "benchmarks": [r.raw for r in self.records]}

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)

    # -- merged-shard documents (repro.core.orchestrate) ----------------
    def shards(self) -> List[Dict[str, Any]]:
        """Per-scope shard metadata of an orchestrator-merged document
        (``[]`` for plain single-run documents)."""
        return list(self.context.get("shards", []))

    def scope_names(self) -> List[str]:
        out: List[str] = []
        for r in self.records:
            s = r.scope
            if s and s not in out:
                out.append(s)
        return out

    def for_scope(self, scope: str) -> "BenchmarkFile":
        """Slice a merged document back into one scope's records."""
        return BenchmarkFile(
            context=self.context,
            records=[r for r in self.records if r.scope == scope],
        )

    # -- manipulation ------------------------------------------------
    def filter_name(self, pattern: str) -> "BenchmarkFile":
        """Paper §V-A.5: keep only records whose name matches ``pattern``."""
        rx = re.compile(pattern)
        return BenchmarkFile(
            context=self.context,
            records=[r for r in self.records if rx.search(r.name)],
        )

    def filter_params(self, params: Dict[str, Any]) -> "BenchmarkFile":
        """Keep records whose name carries every ``axis:value`` pair
        (values compared as strings; a list of values ORs together) —
        the ``--param`` selection applied to a loaded document."""
        def keep(r: BenchmarkRecord) -> bool:
            have = r.params
            for k, want in params.items():
                accepted = [str(v) for v in (
                    want if isinstance(want, (list, tuple)) else [want])]
                if have.get(k) not in accepted:
                    return False
            return True
        return BenchmarkFile(context=self.context,
                             records=[r for r in self.records if keep(r)])

    def param_values(self, key: str) -> List[str]:
        """Distinct values of one parameter axis, in first-seen order —
        what a ``group_by`` spec series expands over."""
        out: List[str] = []
        for r in self.records:
            v = r.params.get(key)
            if v is not None and v not in out:
                out.append(v)
        return out

    def without_aggregates(self) -> "BenchmarkFile":
        return BenchmarkFile(
            context=self.context,
            records=[r for r in self.records
                     if r.get("run_type") != "aggregate"],
        )

    def without_errors(self) -> "BenchmarkFile":
        return BenchmarkFile(
            context=self.context,
            records=[r for r in self.records
                     if not r.get("error_occurred")
                     and not r.get("skipped")],
        )

    def transform(self, field: str, fn) -> "BenchmarkFile":
        """Per-series data transformation (spec files use eval exprs)."""
        out = []
        for r in self.records:
            raw = dict(r.raw)
            if field in raw:
                raw[field] = fn(raw[field])
            out.append(BenchmarkRecord(raw))
        return BenchmarkFile(context=self.context, records=out)

    # -- conversion ------------------------------------------------------
    def to_frame(self, fields: Optional[List[str]] = None) -> Frame:
        """Paper: "converting them to pandas DataFrames"."""
        if not self.records:
            return Frame({})
        if fields is None:
            keys: List[str] = []
            for r in self.records:
                for k in r.raw:
                    if k not in keys:
                        keys.append(k)
            fields = keys
        cols = {k: [r.raw.get(k) for r in self.records] for k in fields}
        return Frame(cols)

    def xy(self, x: str, y: str = "real_time"):
        """Extract (x, y) series; x may be a name-arg (``n``), a record
        field, or the computed field ``real_time_s`` (real_time
        normalized to seconds across time units)."""
        def value(r: BenchmarkRecord, key: str):
            if key == "real_time_s":
                return r.real_time_seconds()
            v = r.get(key)
            return v if v is not None else r.arg(key)

        xs, ys = [], []
        for r in self.records:
            if r.get("run_type") == "aggregate":
                continue
            xv = value(r, x)
            yv = value(r, y)
            if xv is None or yv is None:
                continue
            try:
                xv = float(xv)
            except (TypeError, ValueError):
                pass
            xs.append(xv)
            ys.append(float(yv))
        return xs, ys

    def __iter__(self) -> Iterator[BenchmarkRecord]:
        return iter(self.records)

    def __len__(self) -> int:
        return len(self.records)


def load(path) -> BenchmarkFile:
    """Load a GB-JSON document, or an orchestrator run directory
    (``results/<run-id>/``): its ``merged.json`` when present, else the
    structure-preserving :func:`cat` of every shard in it.  Both
    scope-grained (``<scope>.json``) and benchmark-grained
    (``shards/<instance>.json``, ordered by ``manifest.json``) run
    directories load the same way."""
    import os
    if os.path.isdir(path):
        merged = os.path.join(path, "merged.json")
        if os.path.exists(merged):
            path = merged
        else:
            from repro.core.baseline import run_dir_shard_files
            shards = run_dir_shard_files(path)
            if not shards:
                raise FileNotFoundError(f"no result JSON in {path}")
            return cat([load(p) for p in shards])
    with open(path) as f:
        return BenchmarkFile.from_dict(json.load(f))


def loads(text: str) -> BenchmarkFile:
    return BenchmarkFile.from_dict(json.loads(text))


def cat(files: Iterable[BenchmarkFile]) -> BenchmarkFile:
    """Paper §V-A.4: structure-preserving concatenation.

    Unix ``cat`` would append JSON bodies and yield a malformed result;
    this concatenates the ``benchmarks`` arrays under the first context.
    """
    files = list(files)
    if not files:
        return BenchmarkFile()
    out = BenchmarkFile(context=dict(files[0].context))
    for f in files:
        out.records.extend(f.records)
    return out


def filter_name(f: BenchmarkFile, pattern: str) -> BenchmarkFile:
    return f.filter_name(pattern)
