"""YAML-spec-driven plotting (paper §V-A.1).

A *spec file* controls the plot type (line with error bars, bar plot,
linear-regression plot with error bars), the source JSON file for each data
series, regex filters to extract the desired data, per-series scaling
transformations, and styling.  Mirrors ScopePlot's spec schema::

    title: SAXPY throughput
    type: line            # line | bar | regression
    output: saxpy.png
    x_axis: {label: elements, scale: log}
    y_axis: {label: GB/s}
    series:
      - label: cpu
        input_file: results.json
        regex: "example/saxpy.*"
        xfield: n                  # GB name-arg or record field
        yfield: bytes_per_second
        yscale: 1.0e-9
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import yaml

from .model import BenchmarkFile, load

import matplotlib
matplotlib.use("Agg")                     # headless
import matplotlib.pyplot as plt           # noqa: E402


def load_spec(path: str) -> Dict[str, Any]:
    with open(path) as f:
        spec = yaml.safe_load(f)
    if not isinstance(spec, dict) or "series" not in spec:
        raise ValueError(f"invalid spec file {path!r}: needs a 'series' list")
    return spec


def spec_dependencies(spec: Dict[str, Any]) -> List[str]:
    """Paper §V-A.2 (deps): the JSON files a spec reads."""
    out: List[str] = []
    for s in spec.get("series", []):
        p = s.get("input_file")
        if p and p not in out:
            out.append(p)
    return out


def _series_xy(series: Dict[str, Any], base_dir: str = "."
               ) -> Tuple[List[float], List[float], List[float]]:
    path = series["input_file"]
    if not os.path.isabs(path):
        path = os.path.join(base_dir, path)
    bf = load(path).without_errors()
    if "regex" in series:
        bf = bf.filter_name(series["regex"])
    xs, ys = bf.xy(series.get("xfield", "name"),
                   series.get("yfield", "real_time"))
    xscale = float(series.get("xscale", 1.0))
    yscale = float(series.get("yscale", 1.0))
    xs = [x * xscale if isinstance(x, (int, float)) else x for x in xs]
    ys = [y * yscale for y in ys]
    # error bars: stddev aggregates with matching run_name, if present
    errs: List[float] = []
    agg = {r.get("run_name"): r for r in load(path).records
           if r.get("aggregate_name") == "stddev"}
    if agg:
        for r in bf.records:
            a = agg.get(r.get("run_name"))
            errs.append(float(a.real_time or 0.0) * yscale if a else 0.0)
    return xs, ys, errs


def render_spec(spec: Dict[str, Any], output: Optional[str] = None,
                base_dir: str = ".") -> str:
    ptype = spec.get("type", "line")
    fig, ax = plt.subplots(figsize=spec.get("figsize", (7, 4.5)))
    n_series = len(spec["series"])
    width = 0.8 / max(n_series, 1)

    for i, series in enumerate(spec["series"]):
        xs, ys, errs = _series_xy(series, base_dir)
        label = series.get("label", f"series{i}")
        if ptype == "bar":
            pos = np.arange(len(xs)) + i * width
            ax.bar(pos, ys, width=width, label=label,
                   yerr=errs if any(errs) else None, capsize=3)
            if i == 0:
                ax.set_xticks(np.arange(len(xs)) + 0.4 - width / 2)
                ax.set_xticklabels([str(x) for x in xs], rotation=30,
                                   ha="right", fontsize=8)
        elif ptype == "regression":
            xf = np.asarray(xs, dtype=float)
            yf = np.asarray(ys, dtype=float)
            ax.errorbar(xf, yf, yerr=errs if any(errs) else None, fmt="o",
                        label=label, capsize=3)
            if len(xf) >= 2:
                slope, icept = np.polyfit(xf, yf, 1)
                grid = np.linspace(xf.min(), xf.max(), 64)
                ax.plot(grid, slope * grid + icept, "--",
                        label=f"{label} fit ({slope:.3g}x+{icept:.3g})")
        else:  # line with error bars
            ax.errorbar(xs, ys, yerr=errs if any(errs) else None,
                        marker="o", label=label, capsize=3)

    xaxis = spec.get("x_axis", {})
    yaxis = spec.get("y_axis", {})
    if xaxis.get("label"):
        ax.set_xlabel(xaxis["label"])
    if yaxis.get("label"):
        ax.set_ylabel(yaxis["label"])
    if xaxis.get("scale") == "log" and ptype != "bar":
        ax.set_xscale("log", base=2)
    if yaxis.get("scale") == "log":
        ax.set_yscale("log")
    if spec.get("title"):
        ax.set_title(spec["title"])
    ax.grid(True, alpha=0.3)
    ax.legend(fontsize=8)
    fig.tight_layout()

    out = output or spec.get("output", "scope_plot.png")
    if not os.path.isabs(out):
        out = os.path.join(base_dir, out)
    fig.savefig(out, dpi=spec.get("dpi", 120))
    plt.close(fig)
    return out


def quick_bar(json_path: str, x: str, y: str, title: str = "",
              output: str = "bar.png", regex: str = ".*") -> str:
    """Paper §V-A.3 (bar): one-shot bar plot without a spec file."""
    spec = {
        "title": title or os.path.basename(json_path),
        "type": "bar",
        "output": output,
        "x_axis": {"label": x},
        "y_axis": {"label": y},
        "series": [{"label": y, "input_file": json_path, "regex": regex,
                    "xfield": x, "yfield": y}],
    }
    return render_spec(spec)
