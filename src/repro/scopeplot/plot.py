"""YAML-spec-driven plotting (paper §V-A.1).

A *spec file* controls the plot type, the source JSON file for each data
series, regex filters to extract the desired data, per-series scaling
transformations, and styling.  Mirrors ScopePlot's spec schema::

    title: SAXPY throughput
    type: line            # line | bar | grouped_bar | regression
                          #   | speedup | timeseries
    output: saxpy.png
    x_axis: {label: elements, scale: log}
    y_axis: {label: GB/s}
    series:
      - label: cpu
        input_file: results.json
        regex: "example/saxpy.*"
        xfield: n                  # GB name-arg or record field
        yfield: bytes_per_second
        yscale: 1.0e-9

Typed parameter spaces (repro.core.benchmark.ParamSpace) make two more
series keys useful:

  * ``params: {axis: value}`` — keep only records whose name carries
    the ``axis:value`` component(s) (a value list ORs together);
  * ``group_by: axis`` — expand this series into one plotted series
    per distinct value of the axis, so *one* spec plots e.g. dtype as
    series instead of a hand-written series per family clone
    (unavailable for ``timeseries``, which reads history.jsonl).

Plot types (full schema reference: ``docs/scopeplot.md``):

  * ``line`` — line with error bars (stddev aggregates when present);
  * ``bar`` / ``grouped_bar`` — bars per series; grouped_bar aligns
    series by x *category* (union across series), so runs with
    different instance sets still line up;
  * ``regression`` — scatter + least-squares fit line;
  * ``speedup`` — horizontal bars of ``baseline_time / series_time``
    per matching run_name; needs a top-level ``baseline:`` mapping;
  * ``timeseries`` — cross-run trend lines read from a run-history
    ``history.jsonl`` (one line per benchmark, x = run, y = mean ±
    stddev);
  * ``latency_cdf`` — tail-latency CDF per matching record, drawn
    through the latency meter's percentile-grid counters
    (``latency_p50_s`` … ``latency_p999_s``; ``field:`` selects
    another prefix, e.g. ``ttft``) with a log-scaled probability axis
    so p99/p999 are readable.

Error contract: :func:`load_spec` raises :class:`SpecError` (a
``ValueError``) with ``<path>:<line>: <message>`` *before* any data is
read or rendered — an invalid ``type``, ``output`` or ``series`` fails
at the offending spec line, not deep inside matplotlib.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import matplotlib
import numpy as np
import yaml

from .model import load

matplotlib.use("Agg")                     # headless
import matplotlib.pyplot as plt           # noqa: E402

#: Every plot type render_spec understands.
PLOT_TYPES = ("line", "bar", "grouped_bar", "regression", "speedup",
              "timeseries", "latency_cdf")


class SpecError(ValueError):
    """A spec file failed validation; message carries ``path:line:``."""

    def __init__(self, path: str, line: int, message: str):
        self.path = path
        self.line = line
        where = f"{path}:{line}" if line else path
        super().__init__(f"{where}: {message}")


def _key_lines(text: str) -> Dict[str, int]:
    """1-based line number of every top-level mapping key."""
    try:
        node = yaml.compose(text)
    except yaml.YAMLError:
        return {}
    out: Dict[str, int] = {}
    if isinstance(node, yaml.MappingNode):
        for k, _ in node.value:
            if isinstance(k, yaml.ScalarNode):
                out[str(k.value)] = k.start_mark.line + 1
    return out


def load_spec(path: str) -> Dict[str, Any]:
    """Load + validate a spec file; all schema errors carry line numbers.

    Validated up front (the error contract documented in
    ``docs/scopeplot.md``): ``type`` must be one of :data:`PLOT_TYPES`,
    ``output`` a string path, ``series`` a non-empty list of mappings
    each naming an ``input_file``, and a ``speedup`` spec must carry a
    ``baseline: {input_file: ...}`` mapping.
    """
    with open(path) as f:
        text = f.read()
    try:
        spec = yaml.safe_load(text)
    except yaml.YAMLError as e:
        mark = getattr(e, "problem_mark", None)
        line = mark.line + 1 if mark is not None else 0
        raise SpecError(path, line, f"invalid YAML ({e})") from e
    if not isinstance(spec, dict):
        raise SpecError(path, 1, "spec must be a YAML mapping "
                                 f"(got {type(spec).__name__})")
    lines = _key_lines(text)

    ptype = spec.get("type", "line")
    if ptype not in PLOT_TYPES:
        raise SpecError(path, lines.get("type", 1),
                        f"unknown plot type {ptype!r} (expected one of: "
                        + ", ".join(PLOT_TYPES) + ")")
    out = spec.get("output")
    if out is not None and not isinstance(out, str):
        raise SpecError(path, lines.get("output", 1),
                        "'output' must be a string path "
                        f"(got {type(out).__name__})")
    series = spec.get("series")
    if not isinstance(series, list) or not series:
        raise SpecError(path, lines.get("series", 1),
                        "spec needs a non-empty 'series' list")
    sline = lines.get("series", 1)
    for i, s in enumerate(series):
        if not isinstance(s, dict):
            raise SpecError(path, sline,
                            f"series[{i}] must be a mapping "
                            f"(got {type(s).__name__})")
        if not s.get("input_file"):
            raise SpecError(path, sline,
                            f"series[{i}] needs an 'input_file'")
        if "params" in s and not isinstance(s["params"], dict):
            raise SpecError(path, sline,
                            f"series[{i}] 'params' must be a mapping "
                            f"(got {type(s['params']).__name__})")
        if "group_by" in s:
            if not isinstance(s["group_by"], str):
                raise SpecError(path, sline,
                                f"series[{i}] 'group_by' must be an axis "
                                f"name (got {type(s['group_by']).__name__})")
            if ptype == "timeseries":
                raise SpecError(path, sline,
                                f"series[{i}]: 'group_by' is not available "
                                "for timeseries specs (history records "
                                "already plot one line per benchmark)")
    if ptype == "speedup":
        base = spec.get("baseline")
        if not isinstance(base, dict) or not base.get("input_file"):
            raise SpecError(path, lines.get("baseline", lines.get("type", 1)),
                            "speedup spec needs a 'baseline' mapping with "
                            "an 'input_file'")
    return spec


def spec_dependencies(spec: Dict[str, Any]) -> List[str]:
    """Paper §V-A.2 (deps): the data files a spec reads."""
    out: List[str] = []
    for s in spec.get("series", []):
        p = s.get("input_file")
        if p and p not in out:
            out.append(p)
    base = spec.get("baseline")
    if isinstance(base, dict):
        p = base.get("input_file")
        if p and p not in out:
            out.append(p)
    return out


def _resolve(path: str, base_dir: str) -> str:
    return path if os.path.isabs(path) else os.path.join(base_dir, path)


def _series_xy(series: Dict[str, Any], base_dir: str = "."
               ) -> Tuple[List[float], List[float], List[float]]:
    path = _resolve(series["input_file"], base_dir)
    bf = load(path).without_errors()
    if "regex" in series:
        bf = bf.filter_name(series["regex"])
    if "params" in series:
        bf = bf.filter_params(series["params"])
    xs, ys = bf.xy(series.get("xfield", "name"),
                   series.get("yfield", "real_time"))
    xscale = float(series.get("xscale", 1.0))
    yscale = float(series.get("yscale", 1.0))
    xs = [x * xscale if isinstance(x, (int, float)) else x for x in xs]
    ys = [y * yscale for y in ys]
    # error bars: stddev aggregates with matching run_name, if present
    errs: List[float] = []
    agg = {r.get("run_name"): r for r in load(path).records
           if r.get("aggregate_name") == "stddev"}
    if agg:
        for r in bf.records:
            a = agg.get(r.get("run_name"))
            errs.append(float(a.real_time or 0.0) * yscale if a else 0.0)
    return xs, ys, errs


def _mean_times(source: Dict[str, Any], base_dir: str) -> Dict[str, float]:
    """run_name → mean seconds for a {input_file, regex?} mapping."""
    bf = load(_resolve(source["input_file"], base_dir)).without_errors() \
        .without_aggregates()
    if "regex" in source:
        bf = bf.filter_name(source["regex"])
    if "params" in source:
        bf = bf.filter_params(source["params"])
    pools: Dict[str, List[float]] = {}
    for r in bf.records:
        t = r.real_time_seconds()
        if t is not None:
            pools.setdefault(r.get("run_name") or r.name, []).append(t)
    return {name: sum(ts) / len(ts) for name, ts in pools.items() if ts}


def _expand_group_by(spec: Dict[str, Any], base_dir: str
                     ) -> Dict[str, Any]:
    """Expand every ``group_by: axis`` series into one concrete series
    per distinct value of that axis (series-by-param: one spec plots
    dtype as series instead of a series per family clone)."""
    if not any("group_by" in s for s in spec.get("series", [])):
        return spec
    out: List[Dict[str, Any]] = []
    for series in spec["series"]:
        key = series.get("group_by")
        if not key:
            out.append(series)
            continue
        bf = load(_resolve(series["input_file"], base_dir))
        if "regex" in series:
            bf = bf.filter_name(series["regex"])
        if "params" in series:
            bf = bf.filter_params(series["params"])
        values = bf.param_values(key)
        base_label = series.get("label")
        for value in values:
            expanded = {k: v for k, v in series.items() if k != "group_by"}
            expanded["params"] = {**series.get("params", {}), key: value}
            expanded["label"] = (f"{base_label} {key}:{value}"
                                 if base_label else f"{key}:{value}")
            out.append(expanded)
        if not values:
            out.append({k: v for k, v in series.items()
                        if k != "group_by"})
    return {**spec, "series": out}


def _category(x: Any) -> str:
    if isinstance(x, float) and x.is_integer():
        return str(int(x))
    return str(x)


# ---------------------------------------------------------------------------
# per-type renderers
# ---------------------------------------------------------------------------

def _draw_line(ax, spec: Dict[str, Any], base_dir: str) -> None:
    for i, series in enumerate(spec["series"]):
        xs, ys, errs = _series_xy(series, base_dir)
        ax.errorbar(xs, ys, yerr=errs if any(errs) else None,
                    marker="o", label=series.get("label", f"series{i}"),
                    capsize=3)


def _draw_bar(ax, spec: Dict[str, Any], base_dir: str) -> None:
    n_series = len(spec["series"])
    width = 0.8 / max(n_series, 1)
    for i, series in enumerate(spec["series"]):
        xs, ys, errs = _series_xy(series, base_dir)
        pos = np.arange(len(xs)) + i * width
        ax.bar(pos, ys, width=width, label=series.get("label", f"series{i}"),
               yerr=errs if any(errs) else None, capsize=3)
        if i == 0:
            ax.set_xticks(np.arange(len(xs)) + 0.4 - width / 2)
            ax.set_xticklabels([str(x) for x in xs], rotation=30,
                               ha="right", fontsize=8)


def _draw_grouped_bar(ax, spec: Dict[str, Any], base_dir: str) -> None:
    """Bars aligned by x *category* — the union across all series.

    Unlike ``bar`` (which assumes every series yields the same x
    sequence), series with missing categories leave a gap instead of
    shifting their remaining bars onto the wrong ticks.  A category
    repeated *within* one series (e.g. ``xfield: n`` matching two
    families with the same sweep) is disambiguated with an occurrence
    suffix rather than silently dropping the earlier bars.
    """
    categories: List[str] = []
    loaded = []
    for i, series in enumerate(spec["series"]):
        xs, ys, errs = _series_xy(series, base_dir)
        seen: Dict[str, int] = {}
        cats = []
        for x in xs:
            c = _category(x)
            seen[c] = seen.get(c, 0) + 1
            cats.append(c if seen[c] == 1 else f"{c} ({seen[c]})")
        for c in cats:
            if c not in categories:
                categories.append(c)
        loaded.append((series.get("label", f"series{i}"),
                       dict(zip(cats, ys)),
                       dict(zip(cats, errs)) if errs else {}))
    n_series = max(len(loaded), 1)
    width = 0.8 / n_series
    idx = np.arange(len(categories))
    for i, (label, ymap, emap) in enumerate(loaded):
        ys = [ymap.get(c, np.nan) for c in categories]
        errs = [emap.get(c, 0.0) for c in categories]
        ax.bar(idx + (i - (n_series - 1) / 2) * width, ys, width=width,
               label=label, yerr=errs if any(errs) else None, capsize=3)
    ax.set_xticks(idx)
    ax.set_xticklabels(categories, rotation=30, ha="right", fontsize=8)


def _draw_regression(ax, spec: Dict[str, Any], base_dir: str) -> None:
    for i, series in enumerate(spec["series"]):
        xs, ys, errs = _series_xy(series, base_dir)
        label = series.get("label", f"series{i}")
        xf = np.asarray(xs, dtype=float)
        yf = np.asarray(ys, dtype=float)
        ax.errorbar(xf, yf, yerr=errs if any(errs) else None, fmt="o",
                    label=label, capsize=3)
        if len(xf) >= 2:
            slope, icept = np.polyfit(xf, yf, 1)
            grid = np.linspace(xf.min(), xf.max(), 64)
            ax.plot(grid, slope * grid + icept, "--",
                    label=f"{label} fit ({slope:.3g}x+{icept:.3g})")


def _draw_speedup(ax, spec: Dict[str, Any], base_dir: str) -> None:
    """Horizontal bars of baseline_time / series_time (>1 = faster)."""
    base = _mean_times(spec["baseline"], base_dir)
    labels: List[str] = []
    values: List[float] = []
    colors: List[str] = []
    for i, series in enumerate(spec["series"]):
        cur = _mean_times(series, base_dir)
        tag = series.get("label", f"series{i}")
        for name in cur:
            if name not in base or cur[name] <= 0:
                continue
            sp = base[name] / cur[name]
            labels.append(name if len(spec["series"]) == 1
                          else f"{name} [{tag}]")
            values.append(sp)
            colors.append("tab:green" if sp >= 1.0 else "tab:red")
    pos = np.arange(len(labels))
    ax.barh(pos, values, color=colors, alpha=0.8)
    ax.set_yticks(pos)
    ax.set_yticklabels(labels, fontsize=8)
    ax.invert_yaxis()
    ax.axvline(1.0, color="k", linewidth=1)
    for p, v in zip(pos, values):
        ax.annotate(f"{v:.2f}x", (v, p), xytext=(3, 0),
                    textcoords="offset points", va="center", fontsize=8)


def _draw_timeseries(ax, spec: Dict[str, Any], base_dir: str) -> None:
    """Cross-run trend from a history.jsonl (repro.core.history).

    The x axis is the union of every series' run order (first-seen
    across series), so multiple series reading different history files
    share one correctly-labeled axis instead of each being plotted
    against the first file's run order.
    """
    from repro.core.history import load_history, run_ids
    loaded = [(series,
               load_history(_resolve(series["input_file"], base_dir)))
              for series in spec["series"]]
    tick_runs: List[str] = []
    for _, records in loaded:
        for rid in run_ids(records):
            if rid not in tick_runs:
                tick_runs.append(rid)
    run_index = {rid: k for k, rid in enumerate(tick_runs)}
    for series, records in loaded:
        if series.get("benchmark"):
            records = [r for r in records
                       if r.get("name") == series["benchmark"]]
        elif series.get("regex"):
            import re
            rx = re.compile(series["regex"])
            records = [r for r in records if rx.search(r.get("name", ""))]
        yscale = float(series.get("yscale", 1.0))
        by_name: Dict[str, List[Dict[str, Any]]] = {}
        for r in records:
            if r.get("mean_s") is not None:
                by_name.setdefault(r["name"], []).append(r)
        for name, recs in by_name.items():
            xs = [run_index[r["run_id"]] for r in recs
                  if r.get("run_id") in run_index]
            ys = [float(r["mean_s"]) * yscale for r in recs
                  if r.get("run_id") in run_index]
            errs = [float(r.get("stddev_s") or 0.0) * yscale for r in recs
                    if r.get("run_id") in run_index]
            label = name if len(by_name) > 1 else \
                series.get("label", name)
            ax.errorbar(xs, ys, yerr=errs if any(errs) else None,
                        marker="o", label=label, capsize=3)
    ax.set_xticks(range(len(tick_runs)))
    ax.set_xticklabels(tick_runs, rotation=30, ha="right", fontsize=8)
    ax.margins(x=0.05)


def _draw_latency_cdf(ax, spec: Dict[str, Any], base_dir: str) -> None:
    """Tail-latency CDF per record from percentile-grid counters.

    The latency meter puts p50/p90/p99/p999 on every record; each
    matching record becomes one CDF line through those four points
    (x = latency, y = cumulative fraction).  ``field:`` on a series
    switches the counter prefix (default ``latency``; ``ttft`` plots
    first-token CDFs).  The y axis plots ``1 - q`` on a log scale when
    ``y_axis: {scale: log}`` is requested, which is the standard way to
    make the p99/p999 decades readable.
    """
    from repro.core.quantile import TAIL_QUANTILES
    tail = spec.get("y_axis", {}).get("scale") == "log"
    for i, series in enumerate(spec["series"]):
        path = _resolve(series["input_file"], base_dir)
        bf = load(path).without_errors().without_aggregates()
        if "regex" in series:
            bf = bf.filter_name(series["regex"])
        if "params" in series:
            bf = bf.filter_params(series["params"])
        field = series.get("field", "latency")
        xscale = float(series.get("xscale", 1.0))
        tag = series.get("label")
        for rec in bf.records:
            pts = [(float(rec.get(f"{field}_{suffix}_s")) * xscale, q)
                   for suffix, q in TAIL_QUANTILES
                   if rec.get(f"{field}_{suffix}_s") is not None]
            if not pts:
                continue
            xs = [p[0] for p in pts]
            ys = [1.0 - p[1] for p in pts] if tail else [p[1] for p in pts]
            name = rec.get("run_name") or rec.name
            label = f"{name} [{tag}]" if tag and len(spec["series"]) > 1 \
                else name
            ax.plot(xs, ys, marker="o", label=label)
    if tail:
        ax.set_ylabel(spec.get("y_axis", {}).get("label")
                      or "P(latency > x)")


_RENDERERS = {
    "line": _draw_line,
    "bar": _draw_bar,
    "grouped_bar": _draw_grouped_bar,
    "regression": _draw_regression,
    "speedup": _draw_speedup,
    "timeseries": _draw_timeseries,
    "latency_cdf": _draw_latency_cdf,
}


def render_spec(spec: Dict[str, Any], output: Optional[str] = None,
                base_dir: str = ".") -> str:
    ptype = spec.get("type", "line")
    if ptype not in _RENDERERS:
        raise SpecError("<spec>", 0, f"unknown plot type {ptype!r} "
                        "(expected one of: " + ", ".join(PLOT_TYPES) + ")")
    if ptype != "timeseries":
        spec = _expand_group_by(spec, base_dir)
    fig, ax = plt.subplots(figsize=spec.get("figsize", (7, 4.5)))
    _RENDERERS[ptype](ax, spec, base_dir)

    xaxis = spec.get("x_axis", {})
    yaxis = spec.get("y_axis", {})
    if xaxis.get("label"):
        ax.set_xlabel(xaxis["label"])
    if yaxis.get("label"):
        ax.set_ylabel(yaxis["label"])
    if xaxis.get("scale") == "log" and ptype in ("line", "regression"):
        ax.set_xscale("log", base=2)
    if yaxis.get("scale") == "log":
        ax.set_yscale("log")
    if spec.get("title"):
        ax.set_title(spec["title"])
    ax.grid(True, alpha=0.3)
    if ax.get_legend_handles_labels()[0]:
        ax.legend(fontsize=8)
    fig.tight_layout()

    out = output or spec.get("output", "scope_plot.png")
    out = _resolve(out, base_dir)
    fig.savefig(out, dpi=spec.get("dpi", 120))
    plt.close(fig)
    return out


def quick_bar(json_path: str, x: str, y: str, title: str = "",
              output: str = "bar.png", regex: str = ".*") -> str:
    """Paper §V-A.3 (bar): one-shot bar plot without a spec file."""
    spec = {
        "title": title or os.path.basename(json_path),
        "type": "bar",
        "output": output,
        "x_axis": {"label": x},
        "y_axis": {"label": y},
        "series": [{"label": y, "input_file": json_path, "regex": regex,
                    "xfield": x, "yfield": y}],
    }
    return render_spec(spec)


# ---------------------------------------------------------------------------
# batch mode (paper §V-A.2: deps → rebuild only stale plots)
# ---------------------------------------------------------------------------

def spec_files(spec_dir: str) -> List[str]:
    return sorted(os.path.join(spec_dir, f) for f in os.listdir(spec_dir)
                  if f.endswith((".yaml", ".yml")))


def is_stale(spec_path: str, spec: Dict[str, Any]) -> bool:
    """True when the spec's output is missing or older than any input.

    Inputs are the spec file itself plus every data dependency
    (:func:`spec_dependencies`) — the same file set ``scope_plot deps``
    emits for make, applied directly.
    """
    base = os.path.dirname(spec_path) or "."
    out = _resolve(spec.get("output", "scope_plot.png"), base)
    if not os.path.exists(out):
        return True
    out_mtime = os.path.getmtime(out)
    deps = [spec_path] + [_resolve(d, base)
                          for d in spec_dependencies(spec)]
    return any(os.path.exists(d) and os.path.getmtime(d) > out_mtime
               for d in deps)


def render_spec_dir(spec_dir: str, force: bool = False
                    ) -> List[Tuple[str, str, str]]:
    """Render every spec in a directory, skipping up-to-date outputs.

    Relative paths inside each spec resolve against the spec file's own
    directory.  Returns ``(spec_path, output_path, status)`` per spec,
    status one of ``rendered`` / ``fresh`` / ``error: <msg>`` — one bad
    spec doesn't stop the batch.
    """
    results: List[Tuple[str, str, str]] = []
    for path in spec_files(spec_dir):
        base = os.path.dirname(path) or "."
        try:
            spec = load_spec(path)
            out = _resolve(spec.get("output", "scope_plot.png"), base)
            if not force and not is_stale(path, spec):
                results.append((path, out, "fresh"))
                continue
            render_spec(spec, base_dir=base)
            results.append((path, out, "rendered"))
        except (OSError, ValueError) as e:
            results.append((path, "", f"error: {e}"))
    return results
