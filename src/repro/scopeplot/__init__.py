"""repro.scopeplot — the ScopePlot package (paper §V-A).

Object model + manipulation library for Google-Benchmark JSON files, plus a
CLI (``python -m repro.scopeplot``) with the paper's subcommands:

  * ``spec``         — YAML-spec-driven plots (line w/ error bars, bar,
                       regression)
  * ``deps``         — emit make-format dependencies of a spec file
  * ``bar``          — one-shot bar plot without a spec file
  * ``cat``          — structure-preserving concatenation of JSON files
  * ``filter_name``  — keep benchmarks whose name matches a regex
"""
from .model import BenchmarkFile, BenchmarkRecord, cat, filter_name, load, loads
from .frame import Frame

__all__ = [
    "BenchmarkFile", "BenchmarkRecord", "Frame",
    "cat", "filter_name", "load", "loads",
]
