"""repro.scopeplot — the ScopePlot package (paper §V-A).

Object model + manipulation library for Google-Benchmark JSON files, plus a
CLI (``python -m repro.scopeplot``) with the paper's subcommands:

  * ``spec``         — YAML-spec-driven plots (line w/ error bars, bar,
                       grouped_bar, regression, speedup, timeseries)
  * ``batch``        — render a spec directory, rebuilding only stale
                       plots (paper §V-A.2 deps, applied directly)
  * ``report``       — auto-generated HTML/Markdown run report
                       (``--report`` works as an alias)
  * ``deps``         — emit make-format dependencies of a spec file
  * ``bar``          — one-shot bar plot without a spec file
  * ``cat``          — structure-preserving concatenation of JSON files
  * ``filter_name``  — keep benchmarks whose name matches a regex

Full spec-schema reference (every key, every plot type, the error
contract): ``docs/scopeplot.md``.
"""
from .model import BenchmarkFile, BenchmarkRecord, cat, filter_name, load, loads
from .frame import Frame

__all__ = [
    "BenchmarkFile", "BenchmarkRecord", "Frame",
    "cat", "filter_name", "load", "loads",
]
