"""Live dashboard over the result store (``python -m repro report --serve``).

A stdlib :mod:`http.server` — no framework, no matplotlib, no network
dependencies — that serves

  * ``/`` — a server-rendered HTML dashboard: run table, per-instance
    trend **sparklines** (inline SVG over each instance's run-mean
    series), and a drift-alert panel driven by the same windowed
    detector the CLI gate uses (:func:`repro.core.history.detect_drift`);
  * ``/api/*`` — JSON endpoints backed by the store
    (``/api/runs``, ``/api/benchmarks``, ``/api/trend?name=``,
    ``/api/drift?window=``, ``/api/query?...``, ``/api/status``);
  * ``/report/...`` — the static report directory ``repro report``
    just generated, if any.

History is re-read per request via :func:`repro.core.history.
load_history`, which takes the SQLite index fast path when
``history.db`` exists and falls back to scanning the JSONL — the
dashboard always shows the file's current truth, including runs
appended or ingested after the server started.

Tests drive :func:`create_server` directly (``port=0`` picks a free
port); operators get a serving loop from ``repro report --serve``.
"""
from __future__ import annotations

import html
import json
import mimetypes
import os
import posixpath
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional
from urllib.parse import parse_qs, urlparse

from repro.core import history as hist
from repro.core.benchmark import parse_param_filter
from repro.core.logging import get_logger
from repro.store.index import store_status
from repro.store.query import (QueryFilter, aggregate_records,
                               match_record, parse_percentiles)

log = get_logger("dashboard")

_SPARK_W, _SPARK_H = 140, 30

_VERDICT_COLOR = {
    "regression": "#c0392b",
    "improvement": "#27ae60",
    "similar": "#7f8c8d",
    "new": "#2980b9",
}

_PAGE_CSS = """\
body{font-family:system-ui,sans-serif;margin:1.5rem;color:#222}
h1{font-size:1.3rem}h2{font-size:1.05rem;margin-top:1.6rem}
table{border-collapse:collapse;font-size:.85rem}
th,td{border:1px solid #ddd;padding:.25rem .55rem;text-align:left}
th{background:#f5f5f5}
td.num{text-align:right;font-variant-numeric:tabular-nums}
.verdict-regression{color:#c0392b;font-weight:600}
.verdict-improvement{color:#27ae60}
.ok{color:#27ae60}.warn{color:#c0392b;font-weight:600}
code{background:#f5f5f5;padding:0 .2rem}
.footer{margin-top:2rem;font-size:.75rem;color:#888}
"""


def sparkline_svg(values: List[float], color: str = "#2980b9") -> str:
    """Inline SVG sparkline over a run-mean series (empty-safe)."""
    pts = [v for v in values if isinstance(v, (int, float))]
    if len(pts) < 2:
        return ""
    lo, hi = min(pts), max(pts)
    span = (hi - lo) or 1.0
    n = len(pts)
    coords = []
    for i, v in enumerate(pts):
        x = 2 + i * (_SPARK_W - 4) / (n - 1)
        y = _SPARK_H - 3 - (v - lo) / span * (_SPARK_H - 6)
        coords.append(f"{x:.1f},{y:.1f}")
    last_x, last_y = coords[-1].split(",")
    return (f'<svg width="{_SPARK_W}" height="{_SPARK_H}" '
            f'role="img" aria-label="trend">'
            f'<polyline points="{" ".join(coords)}" fill="none" '
            f'stroke="{color}" stroke-width="1.5"/>'
            f'<circle cx="{last_x}" cy="{last_y}" r="2.5" '
            f'fill="{color}"/></svg>')


def _fmt_s(v: Optional[float]) -> str:
    if not isinstance(v, (int, float)):
        return "-"
    if v >= 1.0:
        return f"{v:.3f} s"
    if v >= 1e-3:
        return f"{v * 1e3:.3f} ms"
    return f"{v * 1e6:.1f} µs"


class Dashboard:
    """Query/render logic, independent of the HTTP plumbing."""

    def __init__(self, results_dir: str,
                 report_dir: Optional[str] = None,
                 history_file: Optional[str] = None,
                 window: int = hist.DEFAULT_WINDOW):
        self.results_dir = os.path.abspath(results_dir)
        self.history_file = os.path.abspath(
            history_file or hist.history_path(self.results_dir))
        self.report_dir = os.path.abspath(report_dir) if report_dir \
            else None
        self.window = window
        self._coverage: Optional[Dict[str, Any]] = None

    # -- data ------------------------------------------------------------

    def records(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self.history_file):
            return []
        return hist.load_history(self.history_file)

    def runs(self, records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for rid in hist.run_ids(records):
            rr = hist.for_run(records, rid)
            out.append({
                "run_id": rid,
                "ts": rr[0].get("ts", "") if rr else "",
                "sysinfo": rr[0].get("sysinfo", "") if rr else "",
                "tag": rr[0].get("tag") or "",
                "records": len(rr),
                "regressions": sum(1 for r in rr
                                   if r.get("verdict") == "regression"),
            })
        return out

    def trend(self, records: List[Dict[str, Any]],
              name: str) -> Dict[str, Any]:
        points = [{"run_id": r.get("run_id", ""), "ts": r.get("ts", ""),
                   "mean_s": r.get("mean_s"),
                   "stddev_s": r.get("stddev_s"),
                   "verdict": r.get("verdict", "")}
                  for r in hist.series(records, name)]
        return {"name": name, "points": points}

    def drift(self, records: List[Dict[str, Any]],
              window: Optional[int] = None) -> Dict[str, Any]:
        window = window or self.window
        ids = hist.run_ids(records)
        comps = hist.detect_drift(records, window=window) \
            if len(ids) >= 2 else []
        return {
            "window": window,
            "latest": ids[-1] if ids else None,
            "runs": len(ids),
            "comparisons": [{"name": c.name, "base_time": c.base_time,
                             "new_time": c.new_time, "ratio": c.ratio,
                             "verdict": c.verdict} for c in comps],
        }

    def coverage(self) -> Dict[str, Any]:
        """Fingerprint freshness per scope (fresh/stale/never-run).

        Enumerating the registry imports every scope module (and with
        them JAX + the Pallas kernels), so the result is computed once
        per server lifetime and cached; ``?refresh=1`` invalidates.
        Failures degrade to ``{"error": ...}`` — the dashboard must
        keep serving trends on a box that can't import the kernels.
        """
        if self._coverage is None:
            try:
                from repro.core.fingerprint import (coverage,
                                                    registered_benches)
                from repro.core.sysinfo import build_context, \
                    context_digest
                benches = registered_benches()
                self._coverage = coverage(
                    benches, self.records(),
                    sysinfo=context_digest(build_context()))
            except Exception as e:  # noqa: BLE001 - degrade, don't 500
                log.warning("coverage unavailable: %s", e)
                self._coverage = {"error": str(e)}
        return self._coverage

    def query(self, qs: Dict[str, List[str]]) -> Dict[str, Any]:
        def one(key: str) -> Optional[str]:
            return qs[key][0] if qs.get(key) else None
        flt = QueryFilter(
            scope=one("scope"), family=one("family"), name=one("name"),
            params=parse_param_filter(qs.get("param", [])) or None,
            sysinfo=one("sysinfo"), tag=one("tag"),
            run_id=one("run_id"), since=one("since"), until=one("until"))
        rows = [("", r) for r in self.records() if match_record(r, flt)]
        if one("aggregate") in ("1", "true", "yes"):
            quantiles = parse_percentiles(
                one("percentiles") or "p50,p90,p99")
            return {"filter": flt.describe(),
                    "records": len(rows),
                    "instances": [a.to_json() for a in
                                  aggregate_records(rows, quantiles)]}
        return {"filter": flt.describe(), "records": len(rows),
                "matches": [r for _raw, r in rows]}

    # -- HTML ------------------------------------------------------------

    def index_html(self) -> str:
        records = self.records()
        runs = self.runs(records)
        drift = self.drift(records)
        flagged = [c for c in drift["comparisons"]
                   if c["verdict"] in ("regression", "improvement")]
        e = html.escape
        out = [f"<!doctype html><html><head><meta charset='utf-8'>"
               f"<title>SCOPE dashboard</title>"
               f"<style>{_PAGE_CSS}</style></head><body>",
               f"<h1>SCOPE result store — "
               f"<code>{e(self.history_file)}</code></h1>"]

        out.append(f"<h2>Drift watch (window={drift['window']})</h2>")
        if drift["runs"] < 2:
            out.append("<p>Needs at least two recorded runs.</p>")
        elif not flagged:
            out.append(f"<p class='ok'>No windowed drift: latest run "
                       f"<code>{e(drift['latest'] or '')}</code> is "
                       f"within noise of the pooled window.</p>")
        else:
            out.append(f"<p class='warn'>{len(flagged)} instance(s) "
                       f"drifted in <code>{e(drift['latest'] or '')}"
                       f"</code>:</p><table><tr><th>benchmark</th>"
                       f"<th>window mean</th><th>latest</th><th>ratio"
                       f"</th><th>verdict</th></tr>")
            for c in flagged:
                ratio = f"{c['ratio']:.2f}x" if c["ratio"] else "-"
                out.append(
                    f"<tr><td><code>{e(c['name'])}</code></td>"
                    f"<td class='num'>{_fmt_s(c['base_time'])}</td>"
                    f"<td class='num'>{_fmt_s(c['new_time'])}</td>"
                    f"<td class='num'>{ratio}</td>"
                    f"<td class='verdict-{e(c['verdict'])}'>"
                    f"{e(c['verdict'])}</td></tr>")
            out.append("</table>")

        cov = self._coverage      # panel only if already computed
        if cov is not None and "scopes" in cov:
            t = cov.get("totals", {})
            out.append(f"<h2>Staleness (machine <code>"
                       f"{e((cov.get('sysinfo') or '')[:12])}</code>)"
                       f"</h2>")
            out.append("<table><tr><th>scope</th><th>fresh</th>"
                       "<th>stale</th><th>never run</th></tr>")
            for scope in sorted(cov["scopes"]):
                row = cov["scopes"][scope]
                warn = " class='warn'" if (row.get("stale") or
                                           row.get("never")) else ""
                out.append(
                    f"<tr><td><code>{e(scope)}</code></td>"
                    f"<td class='num'>{row.get('fresh', 0)}</td>"
                    f"<td class='num'{warn}>{row.get('stale', 0)}</td>"
                    f"<td class='num'{warn}>{row.get('never', 0)}</td>"
                    f"</tr>")
            out.append(f"</table><p>{t.get('fresh', 0)} of "
                       f"{cov.get('instances', 0)} instance(s) are "
                       f"fingerprint-fresh; a delta run "
                       f"(<code>repro ci</code>) would re-measure "
                       f"{t.get('stale', 0) + t.get('never', 0)}.</p>")

        out.append("<h2>Runs</h2>")
        if runs:
            out.append("<table><tr><th>run</th><th>timestamp</th>"
                       "<th>machine</th><th>tag</th><th>records</th>"
                       "<th>regressions</th></tr>")
            for r in reversed(runs):        # latest first
                cls = " class='warn'" if r["regressions"] else ""
                out.append(
                    f"<tr><td><code>{e(r['run_id'])}</code></td>"
                    f"<td>{e(r['ts'])}</td>"
                    f"<td><code>{e(r['sysinfo'][:12])}</code></td>"
                    f"<td>{e(r['tag'])}</td>"
                    f"<td class='num'>{r['records']}</td>"
                    f"<td class='num'{cls}>{r['regressions']}</td></tr>")
            out.append("</table>")
        else:
            out.append("<p>No runs recorded yet.</p>")

        out.append("<h2>Instance trends</h2>")
        names = hist.benchmark_names(records)
        if names:
            out.append("<table><tr><th>instance</th><th>trend</th>"
                       "<th>latest</th><th>runs</th><th>verdict</th>"
                       "</tr>")
            for name in names:
                series = hist.series(records, name)
                means = [r.get("mean_s") for r in series
                         if isinstance(r.get("mean_s"), (int, float))]
                last = series[-1] if series else {}
                verdict = last.get("verdict", "") or ""
                color = _VERDICT_COLOR.get(verdict, "#2980b9")
                out.append(
                    f"<tr><td><code>{e(name)}</code></td>"
                    f"<td>{sparkline_svg(means, color)}</td>"
                    f"<td class='num'>{_fmt_s(last.get('mean_s'))}</td>"
                    f"<td class='num'>{len(series)}</td>"
                    f"<td class='verdict-{e(verdict)}'>{e(verdict)}"
                    f"</td></tr>")
            out.append("</table>")
        else:
            out.append("<p>No instances recorded yet.</p>")

        links = ["<a href='/api/runs'>/api/runs</a>",
                 "<a href='/api/drift'>/api/drift</a>",
                 "<a href='/api/status'>/api/status</a>",
                 "<a href='/api/coverage'>/api/coverage</a>",
                 "<a href='/api/query?aggregate=1'>/api/query</a>"]
        if self.report_dir and os.path.isdir(self.report_dir):
            links.insert(0, "<a href='/report/index.html'>static "
                            "report</a>")
        out.append(f"<p class='footer'>{' · '.join(links)} — backed by "
                   f"the result store (docs/result-store.md)</p>")
        out.append("</body></html>")
        return "".join(out)


class DashboardHandler(BaseHTTPRequestHandler):
    """Routes requests to a :class:`Dashboard` (set on the server)."""

    server_version = "scope-dashboard"

    @property
    def dash(self) -> Dashboard:
        return self.server.dashboard        # type: ignore[attr-defined]

    def log_message(self, fmt: str, *args: Any) -> None:
        log.debug("%s %s", self.address_string(), fmt % args)

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _json(self, payload: Any, code: int = 200) -> None:
        body = json.dumps(payload, indent=2, sort_keys=True).encode()
        self._send(code, body, "application/json; charset=utf-8")

    def _static(self, rel: str) -> None:
        root = self.dash.report_dir
        if not root or not os.path.isdir(root):
            self._json({"error": "no static report directory"}, 404)
            return
        # normalize inside the report root; reject anything that escapes
        clean = posixpath.normpath(rel).lstrip("/")
        path = os.path.realpath(os.path.join(root, clean))
        if not (path == root or path.startswith(root + os.sep)) \
                or not os.path.isfile(path):
            self._json({"error": f"no such report file: {rel}"}, 404)
            return
        ctype = mimetypes.guess_type(path)[0] or \
            "application/octet-stream"
        with open(path, "rb") as f:
            self._send(200, f.read(), ctype)

    def do_GET(self) -> None:        # noqa: N802 (http.server API)
        url = urlparse(self.path)
        qs = parse_qs(url.query)
        try:
            if url.path in ("/", "/index.html"):
                self._send(200, self.dash.index_html().encode(),
                           "text/html; charset=utf-8")
            elif url.path == "/api/runs":
                self._json(self.dash.runs(self.dash.records()))
            elif url.path == "/api/benchmarks":
                self._json(hist.benchmark_names(self.dash.records()))
            elif url.path == "/api/trend":
                name = (qs.get("name") or [""])[0]
                if not name:
                    self._json({"error": "trend needs ?name="}, 400)
                    return
                self._json(self.dash.trend(self.dash.records(), name))
            elif url.path == "/api/drift":
                window = None
                if qs.get("window"):
                    window = max(1, int(qs["window"][0]))
                self._json(self.dash.drift(self.dash.records(), window))
            elif url.path == "/api/query":
                self._json(self.dash.query(qs))
            elif url.path == "/api/coverage":
                if (qs.get("refresh") or [""])[0] in ("1", "true"):
                    self.dash._coverage = None
                self._json(self.dash.coverage())
            elif url.path == "/api/status":
                self._json(store_status(self.dash.history_file))
            elif url.path.startswith("/report/"):
                self._static(url.path[len("/report/"):])
            else:
                self._json({"error": f"no such endpoint: {url.path}"},
                           404)
        except (ValueError, OSError) as e:
            self._json({"error": str(e)}, 400)


def create_server(results_dir: str, report_dir: Optional[str] = None,
                  host: str = "127.0.0.1", port: int = 0,
                  history_file: Optional[str] = None,
                  window: int = hist.DEFAULT_WINDOW
                  ) -> ThreadingHTTPServer:
    """A ready-to-serve dashboard server (``port=0`` → ephemeral port).

    Callers own the serving loop: tests run it on a thread and shut it
    down; ``repro report --serve`` calls ``serve_forever()``.
    """
    server = ThreadingHTTPServer((host, port), DashboardHandler)
    server.dashboard = Dashboard(                 # type: ignore[attr-defined]
        results_dir, report_dir=report_dir, history_file=history_file,
        window=window)
    return server


def serve_dashboard(results_dir: str, report_dir: Optional[str] = None,
                    host: str = "127.0.0.1", port: int = 8000,
                    window: int = hist.DEFAULT_WINDOW) -> int:
    """Blocking serve loop for ``python -m repro report --serve``."""
    try:
        server = create_server(results_dir, report_dir=report_dir,
                               host=host, port=port, window=window)
    except OSError as e:
        log.error("cannot bind %s:%d: %s", host, port, e)
        return 1
    bound = server.server_address
    print(f"dashboard: http://{bound[0]}:{bound[1]}/  (Ctrl-C stops)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
    return 0
