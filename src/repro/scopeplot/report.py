"""Auto-generated run reports — ``python -m repro report`` (ScopePlot's
"publication-quality plots" promise, turned into a zero-config artifact).

No hand-written YAML needed: given a run directory (and the run-history
store ``results/history.jsonl`` the orchestrator maintains), this module
*generates* a spec per scope, renders it through the normal spec
pipeline (:mod:`repro.scopeplot.plot`), and emits a static
``report/index.html`` + ``report/report.md`` with per-scope sections,
embedded plots, sysinfo, and the verdict table:

  * ``<scope>_times.png``   — grouped-bar of per-instance mean times;
  * ``<scope>_trend.png``   — cross-run time series from history.jsonl
    (appears once the store has any record for the scope; a second run
    adds its point automatically);
  * ``<scope>_speedup.png`` — speedup vs the previous recorded run
    (appears once history holds two runs).

The generated specs are saved under ``report/specs/`` — they are plain
ScopePlot specs, so ``python -m repro.scopeplot batch report/specs``
re-renders them (only the stale ones) after hand-tweaking.

Everything in the report derives from the run artifacts (context date,
sysinfo digest, history records) — regenerating a report from the same
run directory is byte-identical, which is what makes the Markdown
output golden-testable.
"""
from __future__ import annotations

import argparse
import html
import json
import os
import re
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import yaml

from repro.core import history as hist
from repro.core.baseline import _fmt_time, collect_stats
from repro.core.cli_examples import epilog
from repro.core.history import DEFAULT_WINDOW
from repro.core.logging import get_logger

from .model import load
from .plot import load_spec, render_spec

log = get_logger("report")

_SYSINFO_KEYS = (
    "date", "host_name", "machine", "model_name", "num_cpus",
    "jax_version", "backend", "device_count", "device_kind",
    "target_hardware", "xla_flags", "scope_version",
)


# ---------------------------------------------------------------------------
# document assembly (shared by the Markdown and HTML writers)
# ---------------------------------------------------------------------------

class Section:
    """One report section: a heading plus tables/images/paragraphs."""

    def __init__(self, title: str):
        self.title = title
        self.parts: List[Tuple[str, Any]] = []

    def text(self, s: str) -> "Section":
        self.parts.append(("text", s))
        return self

    def table(self, headers: Sequence[str],
              rows: Sequence[Sequence[str]]) -> "Section":
        self.parts.append(("table", (list(headers),
                                     [list(r) for r in rows])))
        return self

    def image(self, caption: str, relpath: str) -> "Section":
        self.parts.append(("image", (caption, relpath)))
        return self


def _write_markdown(path: str, title: str, meta: List[Tuple[str, str]],
                    sections: List[Section]) -> None:
    lines = [f"# {title}", ""]
    for k, v in meta:
        lines.append(f"- {k}: {v}")
    lines.append("")
    for sec in sections:
        lines.append(f"## {sec.title}")
        lines.append("")
        for kind, payload in sec.parts:
            if kind == "text":
                lines.append(payload)
                lines.append("")
            elif kind == "table":
                headers, rows = payload
                lines.append("| " + " | ".join(headers) + " |")
                lines.append("|" + "|".join("---" for _ in headers) + "|")
                for row in rows:
                    lines.append("| " + " | ".join(row) + " |")
                lines.append("")
            elif kind == "image":
                caption, rel = payload
                lines.append(f"![{caption}]({rel})")
                lines.append("")
    with open(path, "w") as f:
        f.write("\n".join(lines).rstrip() + "\n")


_HTML_STYLE = """
body { font-family: -apple-system, 'Segoe UI', Helvetica, Arial,
       sans-serif; margin: 2em auto; max-width: 60em; padding: 0 1em;
       color: #1c1e21; }
h1 { border-bottom: 2px solid #d0d7de; padding-bottom: .3em; }
h2 { border-bottom: 1px solid #d0d7de; padding-bottom: .2em;
     margin-top: 2em; }
table { border-collapse: collapse; margin: 1em 0; font-size: .9em; }
th, td { border: 1px solid #d0d7de; padding: .35em .7em;
         text-align: left; }
th { background: #f6f8fa; }
td.regression { color: #b42318; font-weight: 600; }
td.improvement { color: #067647; font-weight: 600; }
img { max-width: 100%; border: 1px solid #d0d7de; margin: .5em 0; }
ul.meta { list-style: none; padding: 0; color: #57606a; }
"""

_VERDICT_CLASSES = ("regression", "improvement")


def _html_cell(value: str) -> str:
    cls = value.strip().lower()
    if cls in _VERDICT_CLASSES:
        return f'<td class="{cls}">{html.escape(value)}</td>'
    return f"<td>{html.escape(value)}</td>"


def _write_html(path: str, title: str, meta: List[Tuple[str, str]],
                sections: List[Section]) -> None:
    out = ["<!DOCTYPE html>", "<html><head>",
           '<meta charset="utf-8">',
           f"<title>{html.escape(title)}</title>",
           f"<style>{_HTML_STYLE}</style>",
           "</head><body>",
           f"<h1>{html.escape(title)}</h1>",
           '<ul class="meta">']
    for k, v in meta:
        out.append(f"<li><b>{html.escape(k)}</b>: {html.escape(v)}</li>")
    out.append("</ul>")
    for sec in sections:
        out.append(f"<h2>{html.escape(sec.title)}</h2>")
        for kind, payload in sec.parts:
            if kind == "text":
                out.append(f"<p>{html.escape(payload)}</p>")
            elif kind == "table":
                headers, rows = payload
                out.append("<table><tr>"
                           + "".join(f"<th>{html.escape(h)}</th>"
                                     for h in headers) + "</tr>")
                for row in rows:
                    out.append("<tr>" + "".join(_html_cell(c) for c in row)
                               + "</tr>")
                out.append("</table>")
            elif kind == "image":
                caption, rel = payload
                out.append(f'<figure><img src="{html.escape(rel)}" '
                           f'alt="{html.escape(caption)}">'
                           f"<figcaption>{html.escape(caption)}"
                           f"</figcaption></figure>")
    out.append("</body></html>")
    with open(path, "w") as f:
        f.write("\n".join(out) + "\n")


# ---------------------------------------------------------------------------
# spec generation
# ---------------------------------------------------------------------------

def _scope_regex(scope: str) -> str:
    return f"^{re.escape(scope)}/"


def _emit_spec(specs_dir: str, name: str, spec: Dict[str, Any]) -> str:
    """Write one auto-generated spec and render it through the normal
    pipeline (load_spec validates what we generated — the report must
    not bypass the public spec contract)."""
    path = os.path.join(specs_dir, f"{name}.yaml")
    with open(path, "w") as f:
        yaml.safe_dump(spec, f, sort_keys=False)
    return render_spec(load_spec(path), base_dir=specs_dir)


def _rel(target: str, start_dir: str) -> str:
    return os.path.relpath(os.path.abspath(target),
                           os.path.abspath(start_dir))


def _scope_plots(scope: str, specs_dir: str, out_dir: str,
                 merged_path: Optional[str], history_file: Optional[str],
                 prev_doc_path: Optional[str], run_label: str,
                 history_records: Optional[List[Dict[str, Any]]] = None,
                 prev_names: Optional[set] = None,
                 latency: bool = False
                 ) -> List[Tuple[str, str]]:
    """Generate+render this scope's plots; (caption, path rel to out).

    ``history_records`` is the already-loaded content of
    ``history_file`` and ``prev_names`` the benchmark names inside
    ``prev_doc_path`` — passed in so the per-scope loop doesn't reparse
    either file (the rendered specs still read the files themselves —
    generated specs must stay standalone).  ``latency`` adds the
    tail-latency CDF page for scopes whose records carry latency-meter
    percentile counters (``--meters latency``).
    """
    plots: List[Tuple[str, str]] = []
    rx = _scope_regex(scope)
    if merged_path:
        out = _emit_spec(specs_dir, f"{scope}_times", {
            "title": f"{scope} — mean time per instance",
            "type": "grouped_bar",
            "output": f"../{scope}_times.png",
            "x_axis": {"label": "instance"},
            "y_axis": {"label": "mean time (us)"},
            "series": [{"label": run_label,
                        "input_file": _rel(merged_path, specs_dir),
                        "regex": rx, "xfield": "name",
                        "yfield": "real_time_s", "yscale": 1e6}],
        })
        plots.append((f"{scope}: mean time per instance",
                      _rel(out, out_dir)))
    if merged_path and latency:
        out = _emit_spec(specs_dir, f"{scope}_latency", {
            "title": f"{scope} — request latency CDF (per instance)",
            "type": "latency_cdf",
            "output": f"../{scope}_latency.png",
            "x_axis": {"label": "end-to-end latency (ms)"},
            "y_axis": {"label": "fraction of requests"},
            "series": [{"label": run_label,
                        "input_file": _rel(merged_path, specs_dir),
                        "regex": rx, "xscale": 1e3}],
        })
        plots.append((f"{scope}: tail-latency CDF", _rel(out, out_dir)))
    if history_file and os.path.exists(history_file):
        records = history_records if history_records is not None \
            else hist.load_history(history_file)
        if any(r.get("name", "").startswith(scope + "/") for r in records):
            out = _emit_spec(specs_dir, f"{scope}_trend", {
                "title": f"{scope} — mean time per run",
                "type": "timeseries",
                "output": f"../{scope}_trend.png",
                "x_axis": {"label": "run"},
                "y_axis": {"label": "mean time (s)"},
                "series": [{"label": scope,
                            "input_file": _rel(history_file, specs_dir),
                            "regex": rx}],
            })
            plots.append((f"{scope}: trend across runs",
                          _rel(out, out_dir)))
    if prev_doc_path and merged_path:
        if prev_names is None:
            with open(prev_doc_path) as f:
                prev_names = {b.get("run_name") or b.get("name", "")
                              for b in json.load(f).get("benchmarks", [])}
        if any(n.startswith(scope + "/") for n in prev_names):
            out = _emit_spec(specs_dir, f"{scope}_speedup", {
                "title": f"{scope} — speedup vs previous run",
                "type": "speedup",
                "output": f"../{scope}_speedup.png",
                "x_axis": {"label": "speedup (previous / this run)"},
                "baseline": {"input_file": _rel(prev_doc_path, specs_dir),
                             "regex": rx},
                "series": [{"label": "this run",
                            "input_file": _rel(merged_path, specs_dir),
                            "regex": rx}],
            })
            plots.append((f"{scope}: speedup vs previous run",
                          _rel(out, out_dir)))
    return plots


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def _fmt_mean(mean: Optional[float]) -> str:
    return _fmt_time(mean) if mean is not None else "-"


def _compile_times(doc: Dict[str, Any]) -> Dict[str, float]:
    """run_name → warm-phase ``compile_time_s`` (first record wins)."""
    out: Dict[str, float] = {}
    for rec in doc.get("benchmarks", []):
        ct = rec.get("compile_time_s")
        name = rec.get("run_name") or rec.get("name", "")
        if ct is not None and name not in out:
            out[name] = float(ct)
    return out


def _fmt_flops_rate(v: float) -> str:
    for scale, prefix in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if v >= scale:
            return f"{v / scale:.2f} {prefix}FLOP/s"
    return f"{v:.0f} FLOP/s"


def _roofline_cells(doc: Dict[str, Any]) -> Dict[str, str]:
    """run_name → formatted cost-model cell, for runs measured with the
    cost-model meter (``--meters costmodel``, docs/measurement.md).

    Empty when no record carries cost counters — the verdict table then
    keeps its historical column set, so reports from default runs stay
    byte-identical.
    """
    counters = hist.doc_counters(doc)
    out: Dict[str, str] = {}
    for name, c in counters.items():
        fps, ai = c.get("flops_per_second"), c.get("arithmetic_intensity")
        if fps:
            cell = _fmt_flops_rate(fps)
            if ai:
                cell += f" @ {ai:.2f} F/B"
            out[name] = cell
        elif ai:
            out[name] = f"{ai:.2f} F/B"
    return out


def _latency_cells(doc: Dict[str, Any]) -> Dict[str, Tuple[str, str]]:
    """run_name → (p99 latency, goodput) cells, for runs measured with
    the latency meter (``--meters latency``, docs/serving.md).

    Empty when no record carries tail-percentile counters — like the
    roofline column, the verdict table only grows these columns when
    the data exists, so reports from default runs stay byte-identical.
    """
    counters = hist.doc_counters(doc)
    out: Dict[str, Tuple[str, str]] = {}
    for name, c in counters.items():
        p99 = c.get("latency_p99_s")
        good = c.get("goodput_rps")
        if p99 is None and good is None:
            continue
        out[name] = (_fmt_time(p99) if p99 is not None else "-",
                     f"{good:.1f} req/s" if good is not None else "-")
    return out


def _verdict_rows(doc: Dict[str, Any],
                  run_records: List[Dict[str, Any]],
                  roofline: Optional[Dict[str, str]] = None,
                  latency: Optional[Dict[str, Tuple[str, str]]] = None
                  ) -> List[List[str]]:
    """benchmark | mean | stddev | n | compile | [roofline] | [p99 |
    goodput] | vs previous | ratio — the roofline and latency columns
    appear only when their metrics are present (pass the non-empty
    ``_roofline_cells`` / ``_latency_cells`` results)."""
    by_name = {r["name"]: r for r in run_records}
    compile_by_name = _compile_times(doc)
    rows: List[List[str]] = []
    for name, st in collect_stats(doc).items():
        rec = by_name.get(name, {})
        mean = st.mean if st.has_times else None
        ratio = rec.get("ratio")
        row = [
            name, _fmt_mean(mean),
            _fmt_time(st.stddev) if st.n > 1 else "-",
            str(st.n),
            _fmt_mean(compile_by_name.get(name)),
        ]
        if roofline:
            row.append(roofline.get(name, "-"))
        if latency:
            p99, good = latency.get(name, ("-", "-"))
            row += [p99, good]
        row += [
            rec.get("verdict", "-"),
            f"{ratio:.2f}x" if ratio is not None else "-",
        ]
        rows.append(row)
    return rows


def _drift_section(records: List[Dict[str, Any]], window: int) -> Section:
    sec = Section(f"Drift watch (window={window})")
    ids = hist.run_ids(records)
    if len(ids) < 2:
        sec.text("Needs at least two recorded runs; run again to start "
                 "the trend.")
        return sec
    comps = hist.detect_drift(records, window=window)
    flagged = [c for c in comps
               if c.verdict in ("regression", "improvement")]
    sec.text(f"Latest run `{ids[-1]}` vs the pooled window of up to "
             f"{window} prior run(s).")
    if not flagged:
        sec.text("No windowed drift detected.")
        return sec
    sec.table(
        ["benchmark", "window mean", "latest", "ratio", "verdict"],
        [[c.name, _fmt_mean(c.base_time), _fmt_mean(c.new_time),
          f"{c.ratio:.2f}x" if c.ratio is not None else "-", c.verdict]
         for c in flagged])
    return sec


def _sysinfo_section(ctx: Dict[str, Any]) -> Section:
    from repro.core.sysinfo import context_digest
    sec = Section("System")
    rows = [[k, str(ctx.get(k))] for k in _SYSINFO_KEYS if ctx.get(k)]
    rows.append(["sysinfo digest", context_digest(ctx)])
    return sec.table(["key", "value"], rows)


# ---------------------------------------------------------------------------
# report generators
# ---------------------------------------------------------------------------

def generate_run_report(run_dir: str, history_file: Optional[str] = None,
                        out_dir: Optional[str] = None,
                        window: int = DEFAULT_WINDOW,
                        title: Optional[str] = None) -> Dict[str, str]:
    """Render one run's report; returns {'md': ..., 'html': ...}.

    ``history_file`` defaults to ``history.jsonl`` next to the run
    directory (i.e. the results root the orchestrator appends to).
    """
    run_dir = os.path.abspath(run_dir)
    bf = load(run_dir)
    ctx = bf.context
    run_id = ctx.get("run_id") or os.path.basename(run_dir)
    if history_file is None:
        history_file = hist.history_path(os.path.dirname(run_dir))
    out_dir = os.path.abspath(out_dir or os.path.join(run_dir, "report"))
    specs_dir = os.path.join(out_dir, "specs")
    data_dir = os.path.join(out_dir, "data")
    os.makedirs(specs_dir, exist_ok=True)
    os.makedirs(data_dir, exist_ok=True)

    merged_path = os.path.join(run_dir, "merged.json")
    if not os.path.exists(merged_path):
        # interrupted run: materialize the shard concatenation so the
        # generated specs have a real file to reference
        merged_path = os.path.join(data_dir, "merged.json")
        bf.save(merged_path)

    records = hist.load_history(history_file) \
        if os.path.exists(history_file) else []
    run_records = hist.for_run(records, run_id)
    # Everything comparative is scoped to history *up to the reported
    # run*: reporting an older run must compare it against the runs
    # before it, never against runs recorded after it.
    ids = hist.run_ids(records)
    if run_id in ids:
        prior_ids = ids[:ids.index(run_id)]
        upto = set(prior_ids) | {run_id}
        scoped_records = [r for r in records if r.get("run_id") in upto]
    else:
        prior_ids = ids
        scoped_records = records
    prev_doc_path = None
    prev_names: set = set()
    if prior_ids:
        prev_doc_path = os.path.join(data_dir, "prev.json")
        prev_doc = hist.window_document(
            hist.for_run(records, prior_ids[-1]), window=1)
        prev_names = {b.get("run_name") or b.get("name", "")
                      for b in prev_doc["benchmarks"]}
        with open(prev_doc_path, "w") as f:
            json.dump(prev_doc, f, indent=2)
    # the trend plots must not leak runs recorded *after* the reported
    # run into its report: reporting an older run reads a materialized
    # prefix of the store instead of the live file
    plot_history_file = history_file
    if records and len(scoped_records) != len(records):
        plot_history_file = os.path.join(data_dir, "history.jsonl")
        with open(plot_history_file, "w") as f:
            for r in scoped_records:
                f.write(json.dumps(r) + "\n")

    scopes = bf.scope_names()
    sections: List[Section] = [_sysinfo_section(ctx)]

    shard_meta = bf.shards()
    if shard_meta:
        sections.append(Section("Scopes").table(
            ["scope", "status", "duration"],
            [[s.get("scope", "?"), s.get("status", "?"),
              f"{s.get('duration_s', 0.0):.2f}s"] for s in shard_meta]))

    verdicts = Section("Verdicts")
    if run_records:
        verdicts.text("`vs previous` is each instance's verdict against "
                      "its previous history record.")
    else:
        verdicts.text("No history records for this run — verdicts appear "
                      "once the run is recorded in history.jsonl.")
    doc = bf.to_dict()
    roofline = _roofline_cells(doc)
    latency = _latency_cells(doc)
    headers = ["benchmark", "mean", "stddev", "n", "compile"]
    if roofline:
        headers.append("roofline")
    if latency:
        headers += ["p99 latency", "goodput"]
    headers += ["vs previous", "ratio"]
    verdicts.table(headers,
                   _verdict_rows(doc, run_records, roofline, latency))
    sections.append(verdicts)
    sections.append(_drift_section(scoped_records, window))

    for scope in scopes:
        sec = Section(f"Scope: {scope}")
        plots = _scope_plots(scope, specs_dir, out_dir, merged_path,
                             plot_history_file if scoped_records else None,
                             prev_doc_path, f"run {run_id}",
                             history_records=scoped_records,
                             prev_names=prev_names,
                             latency=any(n.startswith(scope + "/")
                                         for n in latency))
        if not plots:
            sec.text("No plottable records.")
        for caption, rel in plots:
            sec.image(caption, rel)
        sections.append(sec)

    title = title or f"SCOPE benchmark report — run {run_id}"
    meta = [
        ("run", f"`{run_id}`"),
        ("run date", str(ctx.get("date", "unknown"))),
        ("records", f"{len(bf)} across {len(scopes)} scope(s)"),
        ("history", f"{len(hist.run_ids(records))} recorded run(s)"
         if records else "no history records"),
    ]
    md = os.path.join(out_dir, "report.md")
    html_path = os.path.join(out_dir, "index.html")
    _write_markdown(md, title, meta, sections)
    _write_html(html_path, title, meta, sections)
    log.info("report: wrote %s and %s", md, html_path)
    return {"md": md, "html": html_path}


def _tune_speedup_plot(summary: Dict[str, Any], specs_dir: str,
                       data_dir: str, out_dir: str
                       ) -> List[Tuple[str, str]]:
    """Before/after speedup bars for a tune run: every successful trial
    config (plus a ``<kernel> (best)`` bar) against the builtin-default
    baseline, rendered through the normal ``speedup`` spec pipeline."""
    baseline = summary.get("baseline") or {}
    base_time = (baseline.get("metrics") or {}).get("real_time_s")
    trials = (summary.get("search") or {}).get("trials", [])
    best = summary.get("best") or {}
    if not base_time or not trials:
        return []
    kernel = summary.get("kernel", "kernel")

    def rec(name: str, seconds: float) -> Dict[str, Any]:
        return {"name": name, "run_name": name, "run_type": "iteration",
                "iterations": 1, "real_time": seconds,
                "cpu_time": seconds, "time_unit": "s"}

    names: List[Tuple[str, float]] = []
    for t in trials:
        secs = (t.get("metrics") or {}).get("real_time_s")
        if t.get("error") or not secs:
            continue
        label = "/".join(f"{k}:{v}" for k, v in t["params"].items())
        names.append((label, secs))
    best_time = (best.get("metrics") or {}).get("real_time_s")
    if best_time:
        names.append((f"{kernel} (best)", best_time))
    if not names:
        return []
    before = {"context": {}, "benchmarks": [rec(n, base_time)
                                            for n, _ in names]}
    after = {"context": {}, "benchmarks": [rec(n, s) for n, s in names]}
    before_path = os.path.join(data_dir, "tune_before.json")
    after_path = os.path.join(data_dir, "tune_after.json")
    for path, doc in ((before_path, before), (after_path, after)):
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)
    out = _emit_spec(specs_dir, "tune_speedup", {
        "title": f"{kernel} — speedup vs builtin-default blocks",
        "type": "speedup",
        "output": "../tune_speedup.png",
        "x_axis": {"label": "speedup (builtin default / config)"},
        "baseline": {"input_file": _rel(before_path, specs_dir)},
        "series": [{"label": "tuned",
                    "input_file": _rel(after_path, specs_dir)}],
    })
    return [(f"{kernel}: per-config speedup vs the builtin default",
             _rel(out, out_dir))]


def generate_tune_report(run_dir: str, out_dir: Optional[str] = None,
                         title: Optional[str] = None) -> Dict[str, str]:
    """Render a ``python -m repro tune`` run's report from its
    ``tune.json`` summary: before/after speedup bars per kernel and the
    factorial-screening sensitivity table.  Byte-identical when
    regenerated from the same run directory."""
    run_dir = os.path.abspath(run_dir)
    tune_path = os.path.join(run_dir, "tune.json")
    with open(tune_path) as f:
        summary = json.load(f)
    out_dir = os.path.abspath(out_dir or os.path.join(run_dir, "report"))
    specs_dir = os.path.join(out_dir, "specs")
    data_dir = os.path.join(out_dir, "data")
    os.makedirs(specs_dir, exist_ok=True)
    os.makedirs(data_dir, exist_ok=True)

    search = summary.get("search") or {}
    objective = summary.get("objective", "real_time_s")
    trials = search.get("trials", [])
    kernel = summary.get("kernel", "?")
    best = summary.get("best") or {}
    baseline = summary.get("baseline") or {}

    def fmt_cfg(cfg: Optional[Dict[str, Any]]) -> str:
        return ", ".join(f"{k}={v}" for k, v in (cfg or {}).items()) or "-"

    def fmt_obj(metrics: Optional[Dict[str, Any]]) -> str:
        v = (metrics or {}).get(objective)
        if v is None:
            return "-"
        return _fmt_time(v) if objective.endswith("_s") else f"{v:.4g}"

    overview = Section("Search")
    speedup = summary.get("speedup")
    overview.table(["key", "value"], [
        ["family", str(summary.get("family", "?"))],
        ["instance", str(summary.get("instance", "?"))],
        ["kernel", kernel],
        ["objective", objective],
        ["strategy", str(search.get("strategy", "?"))],
        ["trials", f"{len(trials)} of budget {search.get('budget', '?')}"
                   + (" (budget exhausted)" if search.get("exhausted")
                      else "")],
        ["seed", str(search.get("seed", "?"))],
        ["best config", fmt_cfg(best.get("params"))],
        ["best " + objective, fmt_obj(best.get("metrics"))],
        ["baseline config", fmt_cfg(baseline.get("params"))],
        ["baseline " + objective, fmt_obj(baseline.get("metrics"))],
        ["speedup", f"{speedup:.2f}x" if speedup else "-"],
    ])
    sections = [overview]

    sens = Section("Axis sensitivity (factorial screening)")
    ranking = search.get("sensitivity", [])
    if ranking:
        sens.text("Objective span when one axis moves across its "
                  "extremes with the others held at the space's center "
                  "— larger span = more sensitive axis.")
        sens.table(["rank", "axis", f"{objective} span"],
                   [[str(i + 1), r["axis"], f"{r['span']:.4g}"]
                    for i, r in enumerate(ranking)])
    else:
        sens.text("No screening pass in this run "
                  "(--strategy hillclimb skips it).")
    sections.append(sens)

    frontier = set(search.get("frontier", []))
    tr = Section("Trials")
    rows = []
    for t in trials:
        rows.append([
            str(t["index"]), t.get("phase", "?"),
            fmt_cfg(t.get("params")),
            fmt_obj(t.get("metrics")),
            "yes" if t["index"] in frontier else "",
            t.get("error", ""),
        ])
    tr.table(["#", "phase", "config", objective, "pareto", "error"], rows)
    sections.append(tr)

    plots = Section("Speedup")
    images = _tune_speedup_plot(summary, specs_dir, data_dir, out_dir)
    if images:
        for caption, rel in images:
            plots.image(caption, rel)
    else:
        plots.text("No baseline measurement — speedup bars need the "
                   "builtin-default config to have been measured.")
    sections.append(plots)

    title = title or (f"SCOPE tune report — {kernel} "
                      f"(run {summary.get('run_id', '?')})")
    meta = [
        ("run", f"`{summary.get('run_id', '?')}`"),
        ("kernel", kernel),
        ("family", str(summary.get("family", "?"))),
        ("trials", str(len(trials))),
    ]
    md = os.path.join(out_dir, "report.md")
    html_path = os.path.join(out_dir, "index.html")
    _write_markdown(md, title, meta, sections)
    _write_html(html_path, title, meta, sections)
    log.info("tune report: wrote %s and %s", md, html_path)
    return {"md": md, "html": html_path}


def generate_history_report(history_file: str,
                            out_dir: Optional[str] = None,
                            window: int = DEFAULT_WINDOW,
                            title: Optional[str] = None) -> Dict[str, str]:
    """Cross-run trend report over everything in a history file."""
    history_file = os.path.abspath(history_file)
    records = hist.load_history(history_file)
    out_dir = os.path.abspath(
        out_dir or os.path.join(os.path.dirname(history_file), "report"))
    specs_dir = os.path.join(out_dir, "specs")
    os.makedirs(specs_dir, exist_ok=True)

    ids = hist.run_ids(records)
    run_rows = []
    for rid in ids:
        rr = hist.for_run(records, rid)
        regressions = sum(1 for r in rr if r.get("verdict") == "regression")
        run_rows.append([rid, rr[0].get("ts", "") if rr else "",
                         str(len(rr)), str(regressions)])
    sections = [Section("Runs").table(
        ["run", "timestamp", "records", "regressions"], run_rows)]
    sections.append(_drift_section(records, window))

    scopes: List[str] = []
    for name in hist.benchmark_names(records):
        scope = name.split("/", 1)[0]
        if scope and scope not in scopes:
            scopes.append(scope)
    for scope in scopes:
        sec = Section(f"Scope: {scope}")
        for caption, rel in _scope_plots(scope, specs_dir, out_dir,
                                         None, history_file, None, "",
                                         history_records=records):
            sec.image(caption, rel)
        sections.append(sec)

    title = title or "SCOPE benchmark trend report"
    last_ts = records[-1].get("ts", "unknown") if records else "unknown"
    meta = [
        ("source", f"`{os.path.basename(history_file)}`"),
        ("runs", str(len(ids))),
        ("benchmarks", str(len(hist.benchmark_names(records)))),
        ("latest run", f"`{ids[-1]}` ({last_ts})" if ids else "none"),
    ]
    md = os.path.join(out_dir, "report.md")
    html_path = os.path.join(out_dir, "index.html")
    _write_markdown(md, title, meta, sections)
    _write_html(html_path, title, meta, sections)
    log.info("report: wrote %s and %s", md, html_path)
    return {"md": md, "html": html_path}


# ---------------------------------------------------------------------------
# CLI (python -m repro report / python -m repro.scopeplot report)
# ---------------------------------------------------------------------------

def build_report_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro report",
        description="Generate a static HTML/Markdown report (auto-"
                    "generated specs, embedded plots, verdicts, trends) "
                    "for one run or for the whole run history",
        epilog=epilog("report"),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("run", nargs="?", default=None,
                    help="run id under --results-dir, a run directory "
                         "path, or 'history' for the cross-run trend "
                         "report (optional with --serve: serve the "
                         "dashboard without regenerating)")
    ap.add_argument("--results-dir", default="results",
                    help="where runs and history.jsonl live "
                         "(default: results)")
    ap.add_argument("--output", default=None,
                    help="report directory (default: <run-dir>/report, "
                         "or <results-dir>/report for 'history')")
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW,
                    help=f"runs pooled for drift detection "
                         f"(default {DEFAULT_WINDOW})")
    ap.add_argument("--title", default=None, help="override report title")
    ap.add_argument("--serve", action="store_true",
                    help="after rendering, serve a live dashboard over "
                         "the result store: trend sparklines, drift "
                         "alerts, JSON query endpoints, and the static "
                         "report (repro.scopeplot.dashboard)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="dashboard bind address (default: %(default)s)")
    ap.add_argument("--port", type=int, default=8000,
                    help="dashboard port (default: %(default)s; 0 picks "
                         "a free one)")
    return ap


def _known_runs(results_dir: str) -> List[str]:
    if not os.path.isdir(results_dir):
        return []
    out = []
    for name in sorted(os.listdir(results_dir)):
        d = os.path.join(results_dir, name)
        if os.path.isdir(d) and (
                os.path.exists(os.path.join(d, "merged.json"))
                or os.path.exists(os.path.join(d, "manifest.json"))):
            out.append(name)
    return out


def report_main(argv: Optional[List[str]] = None) -> int:
    ap = build_report_parser()
    ns = ap.parse_args(argv)
    if ns.run is None and not ns.serve:
        ap.error("a run id (or 'history') is required unless --serve "
                 "is given")
    paths: Dict[str, str] = {}
    try:
        if ns.run is None:
            pass                    # --serve only: no regeneration
        elif ns.run == "history":
            path = hist.history_path(ns.results_dir)
            if not os.path.exists(path):
                print(f"error: no history file {path} (runs append to it "
                      f"when --results-dir is used)", file=sys.stderr)
                return 2
            paths = generate_history_report(path, out_dir=ns.output,
                                            window=ns.window,
                                            title=ns.title)
        else:
            run_dir = ns.run if os.path.isdir(ns.run) \
                else os.path.join(ns.results_dir, ns.run)
            if not os.path.isdir(run_dir):
                known = _known_runs(ns.results_dir)
                hint = f"; known runs: {', '.join(known)}" if known \
                    else ""
                print(f"error: no run directory {run_dir}{hint}",
                      file=sys.stderr)
                return 2
            if os.path.exists(os.path.join(run_dir, "tune.json")):
                # an autotuning run: its summary drives a dedicated
                # speedup/sensitivity page instead of the scope report
                paths = generate_tune_report(run_dir, out_dir=ns.output,
                                             title=ns.title)
            else:
                paths = generate_run_report(run_dir, out_dir=ns.output,
                                            window=ns.window,
                                            title=ns.title)
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if paths:
        print(paths["html"])
        print(paths["md"])
    if ns.serve:
        from .dashboard import serve_dashboard
        report_dir = os.path.dirname(paths["html"]) if paths else (
            ns.output or os.path.join(ns.results_dir, "report"))
        return serve_dashboard(ns.results_dir, report_dir=report_dir,
                               host=ns.host, port=ns.port,
                               window=ns.window)
    return 0
