"""A minimal columnar table — the pandas-DataFrame stand-in.

pandas is not installable offline; ScopePlot's library API promises
dataframe conversion, so Frame implements the slice of the DataFrame
surface the plotting and analysis code needs: column access, row filtering,
group-by aggregation, sorting, and CSV export.
"""
from __future__ import annotations

import csv
import io
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np


class Frame:
    def __init__(self, columns: Dict[str, List[Any]]):
        lengths = {len(v) for v in columns.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged columns: { {k: len(v) for k, v in columns.items()} }")
        self._cols: Dict[str, List[Any]] = {k: list(v)
                                            for k, v in columns.items()}
        self._n = next(iter(lengths)) if lengths else 0

    # -- basic access ---------------------------------------------------
    @property
    def columns(self) -> List[str]:
        return list(self._cols)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, key: str) -> List[Any]:
        return self._cols[key]

    def column(self, key: str, dtype=None) -> np.ndarray:
        vals = self._cols[key]
        return np.asarray(vals if dtype is None else vals, dtype=dtype)

    def row(self, i: int) -> Dict[str, Any]:
        return {k: v[i] for k, v in self._cols.items()}

    def rows(self) -> List[Dict[str, Any]]:
        return [self.row(i) for i in range(self._n)]

    # -- manipulation ------------------------------------------------
    def where(self, pred: Callable[[Dict[str, Any]], bool]) -> "Frame":
        idx = [i for i in range(self._n) if pred(self.row(i))]
        return self.take(idx)

    def take(self, idx: Sequence[int]) -> "Frame":
        return Frame({k: [v[i] for i in idx] for k, v in self._cols.items()})

    def sort_by(self, key: str, reverse: bool = False) -> "Frame":
        order = sorted(range(self._n), key=lambda i: self._cols[key][i],
                       reverse=reverse)
        return self.take(order)

    def with_column(self, name: str, values: List[Any]) -> "Frame":
        cols = dict(self._cols)
        cols[name] = list(values)
        return Frame(cols)

    def groupby(self, key: str, agg: Dict[str, Callable[[List[Any]], Any]]
                ) -> "Frame":
        groups: Dict[Any, List[int]] = {}
        for i, v in enumerate(self._cols[key]):
            groups.setdefault(v, []).append(i)
        out: Dict[str, List[Any]] = {key: []}
        for col in agg:
            out[col] = []
        for gval, idx in groups.items():
            out[key].append(gval)
            for col, fn in agg.items():
                out[col].append(fn([self._cols[col][i] for i in idx]))
        return Frame(out)

    # -- export ---------------------------------------------------------
    def to_csv(self, path: Optional[str] = None) -> str:
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow(self.columns)
        for i in range(self._n):
            w.writerow([self._cols[k][i] for k in self.columns])
        text = buf.getvalue()
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def __repr__(self) -> str:
        head = ", ".join(self.columns[:6])
        return f"Frame({self._n} rows: {head})"
