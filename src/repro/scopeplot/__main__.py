"""scope_plot CLI — ``python -m repro.scopeplot <subcommand>`` (paper §V-A).

Subcommands: ``spec`` (render one YAML spec), ``batch`` (render a spec
directory, rebuilding only stale plots — paper §V-A.2's make deps,
applied directly), ``deps``, ``bar``, ``cat``, ``filter_name``, and
``report`` (auto-generated run report; ``--report <run>`` is an alias).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .model import cat, load
from .plot import (SpecError, load_spec, quick_bar, render_spec,
                   render_spec_dir, spec_dependencies)

_EPILOG = """examples:
  $ python -m repro.scopeplot spec saxpy.yaml
  $ python -m repro.scopeplot batch results/20260731T120000-42/report/specs
  $ python -m repro.scopeplot report 20260731T120000-42 --results-dir results
  $ python -m repro.scopeplot bar merged.json --x-field name --y-field real_time
"""


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "--report":
        # alias: python -m repro.scopeplot --report <run> [...]
        from .report import report_main
        return report_main(argv[1:])

    p = argparse.ArgumentParser(
        prog="scope_plot", epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = p.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("spec", help="render a plot from a YAML spec file")
    sp.add_argument("spec_file")
    sp.add_argument("--output", default=None)

    bt = sub.add_parser("batch",
                        help="render every spec in a directory, "
                             "rebuilding only stale outputs")
    bt.add_argument("spec_dir")
    bt.add_argument("--force", action="store_true",
                    help="re-render even up-to-date outputs")

    sub.add_parser("report",
                   help="auto-generated run report (see python -m repro "
                        "report --help)", add_help=False)

    dp = sub.add_parser("deps", help="emit make-format deps of a spec file")
    dp.add_argument("spec_file")
    dp.add_argument("--target", default=None,
                    help="make target name (default: the spec's output)")

    bp = sub.add_parser("bar", help="one-shot bar plot from a JSON file")
    bp.add_argument("json_file")
    bp.add_argument("--x-field", required=True)
    bp.add_argument("--y-field", required=True)
    bp.add_argument("--title", default="")
    bp.add_argument("--output", default="bar.png")
    bp.add_argument("--filter", default=".*")

    cp = sub.add_parser("cat", help="structure-preserving concatenation")
    cp.add_argument("json_files", nargs="+")

    fp = sub.add_parser("filter_name",
                        help="keep benchmarks matching a regex")
    fp.add_argument("json_file")
    fp.add_argument("regex")

    if argv and argv[0] == "report":
        # forwarded wholesale so report's own flags (--results-dir,
        # --window, ...) don't need re-declaring here
        from .report import report_main
        return report_main(argv[1:])

    args = p.parse_args(argv)

    if args.cmd == "spec":
        try:
            spec = load_spec(args.spec_file)
        except SpecError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        out = render_spec(spec, output=args.output)
        print(out)
    elif args.cmd == "batch":
        try:
            results = render_spec_dir(args.spec_dir, force=args.force)
        except OSError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        if not results:
            print(f"error: no spec files (*.yaml/*.yml) in "
                  f"{args.spec_dir}", file=sys.stderr)
            return 2
        failures = 0
        for spec_path, out, status in results:
            print(f"{spec_path}: {status}" + (f" -> {out}" if out else ""))
            if status.startswith("error"):
                failures += 1
        return 1 if failures else 0
    elif args.cmd == "deps":
        try:
            spec = load_spec(args.spec_file)
        except SpecError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        deps = spec_dependencies(spec)
        target = args.target or spec.get("output", "plot.png")
        print(f"{target}: " + " ".join(deps))
    elif args.cmd == "bar":
        out = quick_bar(args.json_file, args.x_field, args.y_field,
                        title=args.title, output=args.output,
                        regex=args.filter)
        print(out)
    elif args.cmd == "cat":
        merged = cat([load(f) for f in args.json_files])
        json.dump(merged.to_dict(), sys.stdout, indent=2)
        print()
    elif args.cmd == "filter_name":
        bf = load(args.json_file).filter_name(args.regex)
        json.dump(bf.to_dict(), sys.stdout, indent=2)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
