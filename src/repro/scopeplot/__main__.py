"""scope_plot CLI — ``python -m repro.scopeplot <subcommand>`` (paper §V-A)."""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .model import BenchmarkFile, cat, load
from .plot import load_spec, quick_bar, render_spec, spec_dependencies


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="scope_plot")
    sub = p.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("spec", help="render a plot from a YAML spec file")
    sp.add_argument("spec_file")
    sp.add_argument("--output", default=None)

    dp = sub.add_parser("deps", help="emit make-format deps of a spec file")
    dp.add_argument("spec_file")
    dp.add_argument("--target", default=None,
                    help="make target name (default: the spec's output)")

    bp = sub.add_parser("bar", help="one-shot bar plot from a JSON file")
    bp.add_argument("json_file")
    bp.add_argument("--x-field", required=True)
    bp.add_argument("--y-field", required=True)
    bp.add_argument("--title", default="")
    bp.add_argument("--output", default="bar.png")
    bp.add_argument("--filter", default=".*")

    cp = sub.add_parser("cat", help="structure-preserving concatenation")
    cp.add_argument("json_files", nargs="+")

    fp = sub.add_parser("filter_name",
                        help="keep benchmarks matching a regex")
    fp.add_argument("json_file")
    fp.add_argument("regex")

    args = p.parse_args(argv)

    if args.cmd == "spec":
        spec = load_spec(args.spec_file)
        out = render_spec(spec, output=args.output)
        print(out)
    elif args.cmd == "deps":
        spec = load_spec(args.spec_file)
        deps = spec_dependencies(spec)
        target = args.target or spec.get("output", "plot.png")
        print(f"{target}: " + " ".join(deps))
    elif args.cmd == "bar":
        out = quick_bar(args.json_file, args.x_field, args.y_field,
                        title=args.title, output=args.output,
                        regex=args.filter)
        print(out)
    elif args.cmd == "cat":
        merged = cat([load(f) for f in args.json_files])
        json.dump(merged.to_dict(), sys.stdout, indent=2)
        print()
    elif args.cmd == "filter_name":
        bf = load(args.json_file).filter_name(args.regex)
        json.dump(bf.to_dict(), sys.stdout, indent=2)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
