"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Each kernel is a subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper with the interpret/TPU switch),
``ref.py`` (pure-jnp oracle).  Kernels are validated on CPU via
``interpret=True`` (the kernel body executes in Python) and tiled for the
TPU v5e memory hierarchy: blocks sized to fit VMEM (~128 MiB/core) with
MXU-aligned (128x128) matmul dims.

SCOPE mapping: the paper's TCU|Scope measures Nvidia tensor cores; our
matmul kernel is the MXU analogue (mxu_scope's measured body).  Histo|Scope
maps to the histogram kernel.  cuDNN|Scope's NN-op bodies map to
flash_attention / rmsnorm / ssd_scan.
"""
