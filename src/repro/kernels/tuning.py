"""Tuned-default registry for the Pallas kernel block knobs.

``python -m repro tune`` searches a kernel's block-size space and writes
the winner to a per-kernel artifact (``src/repro/kernels/<name>/tuned.json``).
This module is how the kernels read it back: every public wrapper in
``kernels/*/ops.py`` resolves its knobs through :func:`resolve` *outside*
jit, so a changed artifact (or a tune-trial override) is picked up on the
next call instead of being frozen into a cached trace.

Precedence, highest first (docs/tuning.md):

  1. explicit kwarg at the call site (``matmul(x, y, bm=256)``)
  2. an active :func:`override` context (how tune trials inject configs)
  3. environment: ``REPRO_TUNED_<KERNEL>_<KNOB>=<int>``
  4. the ``tuned.json`` artifact (skipped entirely when ``REPRO_TUNED``
     is ``off``/``0``/``false``)
  5. the builtin default baked into this module

The module is deliberately jax-free so the search tests and the lint rule
can import it without an accelerator stack.
"""
from __future__ import annotations

import json
import logging
import os
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Mapping, Optional, Tuple

log = logging.getLogger("repro.kernels.tuning")

#: Every tunable kernel and the block knobs ``repro tune`` may set.
KERNEL_KNOBS: Dict[str, Tuple[str, ...]] = {
    "matmul": ("bm", "bn", "bk"),
    "flash_attention": ("bq", "bk"),
    "rmsnorm": ("br",),
    "ssd_scan": ("chunk",),
}

#: Fallback block sizes — the pre-tuning signature defaults.
BUILTIN_DEFAULTS: Dict[str, Dict[str, int]] = {
    "matmul": {"bm": 512, "bn": 512, "bk": 512},
    "flash_attention": {"bq": 512, "bk": 512},
    "rmsnorm": {"br": 256},
    "ssd_scan": {"chunk": 128},
}

#: ``REPRO_TUNED=off|0|false`` disables tuned.json artifacts entirely
#: (env/kwarg/override still apply) — the escape hatch for A/B runs.
DISABLE_ENV = "REPRO_TUNED"

#: Point artifact lookup at ``<dir>/<kernel>/tuned.json`` instead of the
#: installed package tree (tests, hermetic CI workspaces).
DIR_ENV = "REPRO_TUNED_DIR"

#: Conservative per-core VMEM budget for block validation.  The kernels
#: are tiled for TPU v5e (~128 MiB VMEM/core, see repro.kernels); the
#: estimate the wrappers pass in is the single-step working set, doubled
#: for pipelining, so absurd blocks fail here with a readable error
#: instead of deep inside Pallas lowering.
VMEM_BUDGET_BYTES = 128 * 1024 * 1024
VMEM_ENV = "REPRO_VMEM_BUDGET_BYTES"

_TUNED_CACHE: Dict[str, Optional[Dict[str, int]]] = {}
_OVERRIDES: Dict[str, Dict[str, int]] = {}


def kernels() -> Tuple[str, ...]:
    """The tunable kernel names, stable order."""
    return tuple(KERNEL_KNOBS)


def _check_kernel(kernel: str) -> None:
    if kernel not in KERNEL_KNOBS:
        raise ValueError(f"unknown tunable kernel {kernel!r} "
                         f"(known: {', '.join(KERNEL_KNOBS)})")


def tuned_path(kernel: str) -> str:
    """Where ``<kernel>``'s artifact lives (honouring ``REPRO_TUNED_DIR``)."""
    _check_kernel(kernel)
    root = os.environ.get(DIR_ENV) or os.path.dirname(__file__)
    return os.path.join(root, kernel, "tuned.json")


def _artifacts_disabled() -> bool:
    return os.environ.get(DISABLE_ENV, "").lower() in ("off", "0", "false")


def load_tuned(kernel: str) -> Optional[Dict[str, int]]:
    """The artifact's knob config, or None.  Cached per path; a corrupt
    or knob-less artifact logs a warning and acts as absent."""
    path = tuned_path(kernel)
    if path in _TUNED_CACHE:
        return _TUNED_CACHE[path]
    config: Optional[Dict[str, int]] = None
    try:
        with open(path, encoding="utf-8") as fh:
            payload = json.load(fh)
        raw = payload.get("config", {})
        config = {k: int(raw[k]) for k in KERNEL_KNOBS[kernel] if k in raw}
        if not config:
            log.warning("%s carries no known %s knobs; ignoring", path,
                        kernel)
            config = None
    except FileNotFoundError:
        config = None
    except (OSError, ValueError, TypeError, AttributeError) as e:
        log.warning("tuned artifact %s unreadable (%s); using defaults",
                    path, e)
        config = None
    _TUNED_CACHE[path] = config
    return config


def invalidate_cache() -> None:
    """Forget loaded artifacts (call after writing one, or in tests)."""
    _TUNED_CACHE.clear()


@contextmanager
def override(kernel: str, config: Mapping[str, int]) -> Iterator[None]:
    """Force ``kernel``'s knobs for the dynamic extent of the block —
    how ``repro tune`` injects each trial's candidate config without
    touching artifacts or call sites.  Explicit kwargs still win."""
    _check_kernel(kernel)
    bad = [k for k in config if k not in KERNEL_KNOBS[kernel]]
    if bad:
        raise ValueError(f"{kernel} has no knob(s) {', '.join(sorted(bad))} "
                         f"(knobs: {', '.join(KERNEL_KNOBS[kernel])})")
    prev = _OVERRIDES.get(kernel)
    _OVERRIDES[kernel] = {k: int(v) for k, v in config.items()}
    try:
        yield
    finally:
        if prev is None:
            _OVERRIDES.pop(kernel, None)
        else:
            _OVERRIDES[kernel] = prev


def resolve(kernel: str, **explicit: Optional[int]) -> Dict[str, int]:
    """Final knob values for one call: kwarg > override > env > tuned.json
    > builtin.  ``None`` explicit values mean "not given"."""
    _check_kernel(kernel)
    active = _OVERRIDES.get(kernel, {})
    tuned = None if _artifacts_disabled() else load_tuned(kernel)
    out: Dict[str, int] = {}
    for knob in KERNEL_KNOBS[kernel]:
        value = explicit.get(knob)
        if value is None and knob in active:
            value = active[knob]
        if value is None:
            env = os.environ.get(f"REPRO_TUNED_{kernel.upper()}_"
                                 f"{knob.upper()}")
            if env is not None:
                try:
                    value = int(env)
                except ValueError:
                    raise ValueError(
                        f"REPRO_TUNED_{kernel.upper()}_{knob.upper()}="
                        f"{env!r} is not an integer") from None
        if value is None and tuned is not None and knob in tuned:
            value = tuned[knob]
        if value is None:
            value = BUILTIN_DEFAULTS[kernel][knob]
        out[knob] = int(value)
    return out


def write_tuned(kernel: str, payload: Mapping[str, Any],
                path: Optional[str] = None) -> str:
    """Write ``payload`` (must carry a ``config`` mapping of known knobs)
    as the kernel's artifact — canonical JSON, byte-deterministic for
    identical payloads — and invalidate the loader cache."""
    _check_kernel(kernel)
    config = payload.get("config")
    if not isinstance(config, Mapping) or not config:
        raise ValueError("tuned payload needs a non-empty 'config' mapping")
    bad = [k for k in config if k not in KERNEL_KNOBS[kernel]]
    if bad:
        raise ValueError(f"{kernel} has no knob(s) {', '.join(sorted(bad))}")
    out = path or tuned_path(kernel)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    invalidate_cache()
    return out


def vmem_budget_bytes() -> int:
    env = os.environ.get(VMEM_ENV)
    if env:
        try:
            return int(env)
        except ValueError:
            log.warning("%s=%r is not an integer; using default", VMEM_ENV,
                        env)
    return VMEM_BUDGET_BYTES


def validate_blocks(kernel: str, blocks: Mapping[str, int],
                    dims: Mapping[str, int],
                    vmem_bytes: Optional[float] = None) -> None:
    """Fail fast on block configs Pallas would choke on.

    ``blocks`` are the effective (shape-clamped) knob values, ``dims``
    maps each knob to the array dimension it must divide, and
    ``vmem_bytes`` is the wrapper's estimate of the per-grid-step VMEM
    working set (pipelining double-buffer included).  Raises a
    ``ValueError`` naming the offending knob(s) instead of letting the
    kernel die in lowering with a shape assert."""
    _check_kernel(kernel)
    problems = []
    for knob, block in blocks.items():
        dim = dims[knob]
        if block <= 0:
            problems.append(f"{knob}={block} must be positive")
        elif dim % block:
            problems.append(f"{knob}={block} does not divide the "
                            f"dimension it tiles ({dim})")
    budget = vmem_budget_bytes()
    if vmem_bytes is not None and vmem_bytes > budget:
        cfg = ", ".join(f"{k}={v}" for k, v in sorted(blocks.items()))
        problems.append(
            f"blocks ({cfg}) need ~{vmem_bytes / 2 ** 20:.0f} MiB of VMEM "
            f"per grid step, over the {budget / 2 ** 20:.0f} MiB budget")
    if problems:
        raise ValueError(
            f"invalid block config for kernel {kernel!r}: "
            + "; ".join(problems)
            + ".  Pass explicit kwargs, set REPRO_TUNED_"
            + kernel.upper() + "_<KNOB>, or re-run `python -m repro tune` "
            "(REPRO_TUNED=off ignores tuned.json)")
