from .ops import ssd
from .ref import ssd_chunked, ssd_reference

__all__ = ["ssd", "ssd_chunked", "ssd_reference"]
