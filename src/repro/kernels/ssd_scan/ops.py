"""SSD via the Pallas chunk kernel + XLA inter-chunk recurrence.

``chunk`` resolves through :mod:`repro.kernels.tuning` outside the jit
boundary (kwarg > env > tuned.json > builtin).
"""
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import tuning

from .kernel import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk",))
def _ssd(x, dt, A, B, C, D, chunk: int, init_state=None):
    b, l, h, p = x.shape
    interpret = jax.default_backend() != "tpu"
    y_intra, states, ecs = ssd_chunk_pallas(
        x, dt, A, B[:, :, 0], C[:, :, 0], chunk=chunk, interpret=interpret)
    nc = states.shape[1]
    Q = l // nc
    # decay across a whole chunk = exp(a_tot) = ecs at the chunk's last row
    etot = ecs.reshape(b, nc, Q, h)[:, :, -1]            # [b,nc,h]

    h0 = (jnp.zeros((b, h, p, float_n := states.shape[-1]), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def carry(prev, inp):
        s_c, e_c = inp
        new = prev * e_c[:, :, None, None] + s_c
        return new, prev                                  # emit entering state

    hfin, h_in = lax.scan(carry, h0, (jnp.moveaxis(states, 1, 0),
                                      jnp.moveaxis(etot, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                       # [b,nc,h,p,n]
    Cc = C[:, :, 0].astype(jnp.float32).reshape(b, nc, Q, -1)
    ecs_c = ecs.reshape(b, nc, Q, h)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, ecs_c, h_in)
    y = y_intra.astype(jnp.float32) + y_inter.reshape(b, l, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), hfin


def ssd(x, dt, A, B, C, D, *, chunk: Optional[int] = None,
        init_state=None):
    """Same contract as repro.models.layers.ssd_chunked (g=1 folded).

    x [b,l,h,p]; dt [b,l,h]; A [h]; B,C [b,l,g,n]; D [h].
    Returns (y [b,l,h,p], final_state [b,h,p,n]).  ``chunk`` defaults to
    the tuned intra-chunk length.
    """
    cfg = tuning.resolve("ssd_scan", chunk=chunk)
    _, l, _, p = x.shape
    n = B.shape[-1]
    eff = {"chunk": min(cfg["chunk"], l)}
    Q = eff["chunk"]
    # per grid step: x/y chunks, B/C chunks, dt + cumsum rows, the state
    # tile and the three Q x Q decay matrices (all fp32 in-kernel);
    # x2 for the pipeline's double buffer
    vmem = 2 * 4 * (2 * Q * p + 2 * Q * n + 2 * Q + p * n + 3 * Q * Q)
    tuning.validate_blocks("ssd_scan", eff, dims={"chunk": l},
                           vmem_bytes=vmem)
    return _ssd(x, dt, A, B, C, D, eff["chunk"], init_state=init_state)
