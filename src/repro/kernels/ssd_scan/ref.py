"""Pure-jnp oracle: the sequential SSD recurrence."""
from repro.models.layers import ssd_chunked, ssd_reference

__all__ = ["ssd_reference", "ssd_chunked"]
