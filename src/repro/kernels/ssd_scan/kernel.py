"""Mamba2 SSD chunk kernel: intra-chunk output + chunk state, per grid step.

The SSD decomposition splits work into (a) quadratic-in-chunk local terms
and (b) a short inter-chunk recurrence.  This kernel computes (a) plus the
per-chunk states entirely in VMEM — grid (B, H, nc), blocks of one
(batch, head, chunk) each: x [Q,P], dt [Q], B/C [Q,N].  The tiny
inter-chunk scan and the final C·h_in combination stay in XLA (ops.py) —
they are O(nc·P·N) and memory-bound either way.

VMEM at Q=256, P=64, N=128: decay [Q,Q] fp32 + state [P,N] + tiles ≈ 0.6 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, ecs_ref, *, Q: int):
    x = x_ref[0, :, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [Q]
    A = a_ref[0].astype(jnp.float32)                # scalar
    Bm = b_ref[0].astype(jnp.float32)               # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)               # [Q, N]

    a = dt * A                                      # [Q] (negative)
    a_cs = jnp.cumsum(a)                            # inclusive
    # intra-chunk: y_q = sum_{k<=q} exp(a_cs_q - a_cs_k) (C_q·B_k) dt_k x_k
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    decay = jnp.exp(a_cs[:, None] - a_cs[None, :])
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    w = jnp.where(ki <= qi, cb * decay, 0.0) * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q,P]
    # chunk state: S = sum_k exp(a_tot - a_cs_k) dt_k x_k ⊗ B_k   [P,N]
    edecay = jnp.exp(a_cs[-1] - a_cs) * dt                        # [Q]
    state = jax.lax.dot_general(x * edecay[:, None], Bm,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0, 0] = state
    ecs_ref[0, :, 0] = jnp.exp(a_cs)


def ssd_chunk_pallas(x, dt, A, B, C, *, chunk: int = 128,
                     interpret: bool = False):
    """x [b,l,h,p]; dt [b,l,h]; A [h]; B/C [b,l,n] (group dim folded).

    Returns (y_intra [b,l,h,p] fp32-accurate in x.dtype,
             states [b,nc,h,p,n] fp32, exp_a_cs [b,l,h] fp32).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    assert l % Q == 0
    nc = l // Q
    grid = (b, h, nc)
    y, states, ecs = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, Q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, Q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n),
                         lambda bi, hi, ci: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, hi, ci: (bi, ci, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, l, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, states, ecs
