"""Public wrapper: Pallas on TPU, interpret-mode elsewhere.

Block knobs resolve through :mod:`repro.kernels.tuning` (kwarg > env >
tuned.json > builtin) *before* the jit boundary, so a new tuned artifact
or a tune-trial override is honoured on the next call rather than being
frozen into a cached trace keyed on the default.
"""
import functools
from typing import Optional

import jax

from repro.kernels import tuning

from .kernel import matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul(x, y, bm: int, bn: int, bk: int):
    return matmul_pallas(x, y, bm=bm, bn=bn, bk=bk,
                         interpret=not _on_tpu())


def matmul(x, y, *, bm: Optional[int] = None, bn: Optional[int] = None,
           bk: Optional[int] = None):
    """Tiled ``x @ y``; block sizes default to the tuned configuration."""
    cfg = tuning.resolve("matmul", bm=bm, bn=bn, bk=bk)
    M, K = x.shape
    N = y.shape[1]
    eff = {"bm": min(cfg["bm"], M), "bn": min(cfg["bn"], N),
           "bk": min(cfg["bk"], K)}
    # one grid step holds an x block, a y block, the fp32 accumulator
    # scratch and the output block; x2 for the pipeline's double buffer
    vmem = 2 * (eff["bm"] * eff["bk"] * x.dtype.itemsize
                + eff["bk"] * eff["bn"] * y.dtype.itemsize
                + eff["bm"] * eff["bn"] * (4 + x.dtype.itemsize))
    tuning.validate_blocks("matmul", eff, dims={"bm": M, "bn": N, "bk": K},
                           vmem_bytes=vmem)
    return _matmul(x, y, **eff)
