"""Public jit'd wrapper: Pallas on TPU, interpret-mode elsewhere."""
import functools

import jax

from .kernel import matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 512, bn: int = 512, bk: int = 512):
    return matmul_pallas(x, y, bm=bm, bn=bn, bk=bk,
                         interpret=not _on_tpu())
