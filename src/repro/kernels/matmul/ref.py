"""Pure-jnp oracle for the matmul kernel."""
import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)
