from .ops import matmul
from .ref import matmul_ref

__all__ = ["matmul", "matmul_ref"]
