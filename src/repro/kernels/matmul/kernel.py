"""MXU-tiled matmul — the TCU|Scope analogue body.

Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) grid dim so the
fp32 VMEM accumulator carries across K steps and spills to HBM exactly once
per (i, j) tile.  Block sizes default to MXU-aligned 512×512×512 (bf16
working set = 2·512·512·2B + acc 512·512·4B ≈ 2.1 MiB — far under the
~128 MiB v5e VMEM so the pipeline can run several tiles in flight).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x: jax.Array, y: jax.Array, *,
                  bm: int = 512, bn: int = 512, bk: int = 512,
                  out_dtype=None, interpret: bool = False) -> jax.Array:
    """x [M,K] @ y [K,N] with explicit VMEM tiling."""
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (x.shape, y.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"{(M, N, K)} not divisible by {(bm, bn, bk)}"
    nk = K // bk
    out_dtype = out_dtype or x.dtype
    kwargs = dict(
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )
    if _VMEM is not None:
        kwargs["scratch_shapes"] = [_VMEM((bm, bn), jnp.float32)]
        kernel = functools.partial(_matmul_kernel, nk=nk)
    else:  # pragma: no cover - CPU installs always ship pltpu
        raise RuntimeError("pallas TPU scratch unavailable")
    return pl.pallas_call(kernel, **kwargs)(x, y)
