"""Pure-jnp oracle for histogram."""
import jax.numpy as jnp


def histogram_ref(x, nbins: int):
    return jnp.bincount(x, length=nbins).astype(jnp.int32)
