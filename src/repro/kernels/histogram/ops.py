import functools

import jax

from .kernel import histogram_pallas


@functools.partial(jax.jit, static_argnames=("nbins", "chunk"))
def histogram(x, nbins: int, *, chunk: int = 4096):
    return histogram_pallas(x, nbins, chunk=chunk,
                            interpret=jax.default_backend() != "tpu")
