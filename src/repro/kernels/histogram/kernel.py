"""Histogram Pallas kernel — the Histo|Scope body, TPU-adapted.

The CUDA histogram problem is shared-memory atomics; the TPU has no
atomics, but the sequential grid makes privatization trivial: every grid
step accumulates its chunk's counts into the same VMEM-resident output
block (revisited across steps), via a one-hot matmul that feeds the MXU —
the TPU-native replacement for scatter-increment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(x_ref, o_ref, *, nbins: int, chunk: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = x_ref[...]                                     # [chunk] int32
    onehot = (v[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (chunk, nbins), 1))
    o_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)


def histogram_pallas(x: jax.Array, nbins: int, *, chunk: int = 4096,
                     interpret: bool = False) -> jax.Array:
    """x: int32 values in [0, nbins) (1-D); returns int32 [nbins]."""
    n = x.shape[0]
    chunk_ = min(chunk, n)
    assert n % chunk_ == 0, (n, chunk_)
    return pl.pallas_call(
        functools.partial(_hist_kernel, nbins=nbins, chunk=chunk_),
        grid=(n // chunk_,),
        in_specs=[pl.BlockSpec((chunk_,), lambda i: (i,))],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), jnp.int32),
        interpret=interpret,
    )(x)
