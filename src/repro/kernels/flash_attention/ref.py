"""Pure-jnp oracle: repro.models.layers.naive_attention re-export."""
from repro.models.layers import naive_attention as flash_attention_ref

__all__ = ["flash_attention_ref"]
