"""Flash attention (causal/GQA) Pallas kernel — cuDNN|Scope-style NN hot-spot.

Grid (B·H, nq, nk): nk innermost so the online-softmax state (m, l, acc)
lives in VMEM scratch across k-steps and the output tile is written once.
Causal tiles above the diagonal are skipped with ``pl.when`` (the TPU grid
is sequential, so skipped steps cost only the (cheap) predicate).

Tiling: q/o tiles (bq, D), k/v tiles (bk, D).  With bq=bk=512, D=128:
working set ≈ (2·512·128·2 + 512·128·4 + 512·512·4) ≈ 1.6 MiB « VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, bq: int, bk: int, nk: int, scale: float):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * bk <= i * bq + bq - 1) if causal else True

    @pl.when(run if causal else j >= 0)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # [bq, D]
        k = k_ref[0].astype(jnp.float32)              # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(s <= NEG_INF, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(j == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512, bk: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q [B,Sq,H,D]; k/v [B,Sk,K,D] (GQA repeats folded here).

    Layout inside the kernel is [BH, S, D] (head-major) so each grid row
    streams contiguous S×D tiles.
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    bq_, bk_ = min(bq, Sq), min(bk, Sk)
    assert Sq % bq_ == 0 and Sk % bk_ == 0
    nq, nk = Sq // bq_, Sk // bk_
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, causal=causal, bq=bq_, bk=bk_,
                               nk=nk, scale=scale)
    if _VMEM is None:  # pragma: no cover
        raise RuntimeError("pallas TPU scratch unavailable")
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[_VMEM((bq_, 1), jnp.float32),
                        _VMEM((bq_, 1), jnp.float32),
                        _VMEM((bq_, D), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
