"""Public jit'd wrapper for the flash-attention kernel."""
import functools

import jax

from .kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512):
    return flash_attention_pallas(
        q, k, v, causal=causal, bq=bq, bk=bk,
        interpret=jax.default_backend() != "tpu")
