"""Public wrapper for the flash-attention kernel.

``bq``/``bk`` resolve through :mod:`repro.kernels.tuning` outside the
jit boundary (kwarg > env > tuned.json > builtin) so tuned defaults and
tune-trial overrides take effect without retracing stale configs.
"""
import functools
from typing import Optional

import jax

from repro.kernels import tuning

from .kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def _flash_attention(q, k, v, causal: bool, bq: int, bk: int):
    return flash_attention_pallas(
        q, k, v, causal=causal, bq=bq, bk=bk,
        interpret=jax.default_backend() != "tpu")


def flash_attention(q, k, v, *, causal: bool = True,
                    bq: Optional[int] = None, bk: Optional[int] = None):
    """Online-softmax attention; ``bq``/``bk`` default to tuned blocks."""
    cfg = tuning.resolve("flash_attention", bq=bq, bk=bk)
    Sq, Sk = q.shape[2], k.shape[2]
    D = q.shape[-1]
    eff = {"bq": min(cfg["bq"], Sq), "bk": min(cfg["bk"], Sk)}
    # q block + k/v blocks + the bq x bk scores tile + fp32 acc and the
    # m/l running stats + the output block; x2 for double buffering
    vmem = 2 * ((eff["bq"] + 2 * eff["bk"]) * D * q.dtype.itemsize
                + eff["bq"] * eff["bk"] * 4
                + eff["bq"] * (D + 2) * 4
                + eff["bq"] * D * q.dtype.itemsize)
    tuning.validate_blocks("flash_attention", eff,
                           dims={"bq": Sq, "bk": Sk}, vmem_bytes=vmem)
    return _flash_attention(q, k, v, causal, **eff)
