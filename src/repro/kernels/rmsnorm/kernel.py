"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

Grid (nrows/br,): each step loads a [br, d] tile + the [d] scale into VMEM,
computes mean-of-squares in fp32 and writes the normalized tile — XLA's
unfused version reads x twice (square-reduce, then scale).  d up to 8192 at
br=256 → 256·8192·2B ≈ 4 MiB tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
                   br: int = 256, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    xr = x.reshape(-1, d)
    n = xr.shape[0]
    br_ = min(br, n)
    assert n % br_ == 0, (n, br_)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // br_,),
        in_specs=[pl.BlockSpec((br_, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br_, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out.reshape(orig_shape)
