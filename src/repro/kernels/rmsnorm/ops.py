import functools

import jax

from .kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "br"))
def rmsnorm(x, scale, *, eps: float = 1e-6, br: int = 256):
    return rmsnorm_pallas(x, scale, eps=eps, br=br,
                          interpret=jax.default_backend() != "tpu")
