"""Public wrapper for the RMSNorm kernel.

``br`` (rows per grid step) resolves through :mod:`repro.kernels.tuning`
outside the jit boundary (kwarg > env > tuned.json > builtin).
"""
import functools
from typing import Optional

import jax

from repro.kernels import tuning

from .kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "br"))
def _rmsnorm(x, scale, eps: float, br: int):
    return rmsnorm_pallas(x, scale, eps=eps, br=br,
                          interpret=jax.default_backend() != "tpu")


def rmsnorm(x, scale, *, eps: float = 1e-6, br: Optional[int] = None):
    """Row-blocked RMSNorm; ``br`` defaults to the tuned block size."""
    cfg = tuning.resolve("rmsnorm", br=br)
    n, d = x.shape
    eff = {"br": min(cfg["br"], n)}
    # x block + fp32 working copy + output block + the scale row;
    # x2 for the pipeline's double buffer
    vmem = 2 * (eff["br"] * d * (2 * x.dtype.itemsize + 4)
                + d * scale.dtype.itemsize)
    tuning.validate_blocks("rmsnorm", eff, dims={"br": n}, vmem_bytes=vmem)
    return _rmsnorm(x, scale, eps, eff["br"])
