"""Pure-jnp oracle for rmsnorm."""
from repro.models.layers import rms_norm


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    return rms_norm({"scale": scale}, x, eps)
