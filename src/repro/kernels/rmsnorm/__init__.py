from .ops import rmsnorm
from .ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_ref"]
