"""Distributed training driver.

``python -m repro.launch.train --arch llama3.2-1b --steps 200 ...``

Production loop: deterministic resumable data pipeline → pjit'd train step
(microbatched, remat, logical sharding rules) → async checkpoints with
keep-k GC → preemption-safe SIGTERM handling → straggler watchdog → elastic
restart via resharded restore.  On this container the mesh spans local CPU
devices; the identical code path drives the 512-chip production mesh (the
dry-run proves those programs compile).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.core.logging import get_logger
from repro.data import DataConfig, make_pipeline
from repro.distributed import partition as part
from repro.distributed.logical import default_rules, logical_rules
from repro.distributed.straggler import StragglerWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models import build, get_config
from repro.train import AdamWConfig, make_train_step
from repro.train.step import make_init_fn

log = get_logger("train")


def _sharding(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def train(arch: str, steps: int = 100, global_batch: int = 8,
          seq_len: int = 256, lr: float = 3e-4, microbatches: int = 1,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          model_parallel: int = 1, reduced: bool = True,
          log_every: int = 10, seed: int = 0,
          halt_at: Optional[int] = None,
          overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """``halt_at``: stop early (simulated preemption) while keeping the
    ``steps``-horizon LR schedule — resume must continue it exactly."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = cfg.override(**(overrides or {}))
    api = build(cfg)
    mesh = make_host_mesh(model=model_parallel)
    rules = default_rules(cfg, mesh)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps,
                          warmup_steps=max(steps // 20, 5))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)

    init_fn = make_init_fn(api, opt_cfg)
    state_structs = jax.eval_shape(init_fn, jax.random.PRNGKey(seed))
    pspecs = part.param_specs(cfg, state_structs["params"], mesh)
    opt_specs = {"m": part.zero_shard_specs(cfg, state_structs["params"],
                                            mesh),
                 "v": part.zero_shard_specs(cfg, state_structs["params"],
                                            mesh),
                 "count": P()}
    state_specs = {"params": pspecs, "opt": opt_specs, "step": P()}
    state_shardings = _sharding(mesh, state_specs)

    ckpt = CheckpointManager(ckpt_dir, save_interval=ckpt_every) \
        if ckpt_dir else None

    with mesh, logical_rules(rules):
        if ckpt and ckpt.latest_step() is not None:
            host_state, start = ckpt.restore_or_init(
                state_structs, lambda: None)
            state = jax.device_put(host_state, state_shardings)
            log.info("resumed at step %d", start)
        else:
            state = jax.jit(init_fn, out_shardings=state_shardings)(
                jax.random.PRNGKey(seed))
            start = 0

        step_fn = jax.jit(
            make_train_step(api, opt_cfg, num_microbatches=microbatches),
            in_shardings=(state_shardings, None),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,))

        if ckpt:
            latest: Dict[str, Any] = {"step": start, "state": state}
            ckpt.install_signal_handler(
                lambda: (latest["step"], latest["state"]))

        watchdog = StragglerWatchdog(num_hosts=jax.process_count())
        pipe = make_pipeline(data_cfg, start_step=start)
        losses = []
        t_start = time.perf_counter()
        for step, batch in pipe:
            if step >= steps or (halt_at is not None and step >= halt_at):
                break
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.family in ("audio", "encdec"):
                batch["frames"] = jnp.zeros(
                    (batch["tokens"].shape[0], cfg.enc_seq, cfg.d_model),
                    jnp.float32)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            watchdog.record_step(np.asarray([dt]))
            if step % log_every == 0 or step == steps - 1:
                log.info("step %d loss %.4f (%.0f tok/s)", step, loss,
                         global_batch * seq_len / dt)
            if ckpt:
                latest = {"step": step + 1, "state": state}
                ckpt.maybe_save(step + 1, state)
        if ckpt:
            ckpt.wait()
        if hasattr(pipe, "close"):
            pipe.close()

    total = time.perf_counter() - t_start
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": len(losses), "seconds": total,
            "tokens_per_s": len(losses) * global_batch * seq_len / total}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="train")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced)")
    args = ap.parse_args(argv)
    out = train(args.arch, steps=args.steps, global_batch=args.global_batch,
                seq_len=args.seq_len, lr=args.lr,
                microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every,
                model_parallel=args.model_parallel,
                reduced=not args.full_size)
    log.info("done: %s", out)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
