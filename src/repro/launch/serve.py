"""Serving driver: batched requests through the continuous-batching engine.

``python -m repro.launch.serve --arch llama3.2-1b --requests 16``

Uses a reduced config by default (CPU container); the full-size decode
programs for the production mesh are exercised by the dry-run
(decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.logging import get_logger
from repro.models import build, get_config
from repro.serve import ServeConfig, ServeEngine

log = get_logger("serve-main")


def serve_demo(arch: str, n_requests: int = 16, max_tokens: int = 16,
               max_batch: int = 4, reduced: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    engine = ServeEngine(api, params, ServeConfig(
        max_batch=max_batch, max_len=256, prompt_buckets=(16, 32, 64)))
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, size=plen)
        engine.submit(prompt, max_tokens=max_tokens)
    done = engine.run()
    stats = ServeEngine.summarize(done)
    log.info("served %s", stats)
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="serve")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)
    serve_demo(args.arch, args.requests, args.max_tokens, args.max_batch)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
