"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run sees 512 placeholders).

Mesh shapes (TPU v5e pods):
  * single-pod: (16, 16)    axes (data, model)   — 256 chips
  * multi-pod:  (2, 16, 16) axes (pod, data, model) — 512 chips

Axis order is outermost-first so DP gradient reductions decompose
hierarchically: reduce-scatter within a pod over 'data' (ICI), then the
small cross-pod all-reduce over 'pod' (DCN).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests/examples (e.g. (2,4) on 8 CPU devices)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Best-effort mesh over whatever devices exist (CPU smoke runs)."""
    n = jax.device_count()
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
