import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count at first initialization, and the dry-run needs
# 512 placeholder host devices to build the production mesh.  Smoke tests
# and benchmarks never import this module, so they see 1 device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input-shape × mesh) cell:
  1. build the production mesh (16×16 single-pod / 2×16×16 multi-pod);
  2. construct ShapeDtypeStruct stand-ins for every model input (no
     allocation — full-size configs never touch device memory);
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``;
  4. print ``memory_analysis()`` (proves it fits) and ``cost_analysis()``
     (FLOPs/bytes for §Roofline), parse collective bytes from the HLO;
  5. append the cell's record to a results JSON for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import partition as part
from repro.distributed.logical import default_rules, logical_rules
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh
from repro.models import build, get_config, list_archs
from repro.models.config import ModelConfig
from repro.roofline.analysis import analyze_compiled, model_flops
from repro.train import AdamWConfig, make_train_step
from repro.train.step import make_init_fn

RESULTS_DEFAULT = "results/dryrun"


# ---------------------------------------------------------------------------
# per-shape config adjustments (baseline implementation policy, recorded)
# ---------------------------------------------------------------------------

def tune_config(cfg: ModelConfig, shape: str, overrides: Dict[str, Any]
                ) -> ModelConfig:
    """Baseline numerics/memory policy for full-scale lowering.

    remat=full + seq-chunked loss for training; these are the *paper-
    faithful baseline* settings — §Perf hillclimbing changes them per-cell
    and records deltas.
    """
    tuned: Dict[str, Any] = {}
    kind = inp.SHAPES[shape].kind
    if kind == "train":
        tuned.update(remat="full", loss_chunk=1024)
    if kind == "prefill":
        tuned.update(loss_chunk=0)
    tuned.update({k: v for k, v in overrides.items()
                  if k not in ("microbatches", "param_mode", "dp_layout", "no_grad_spec")})
    return cfg.override(**tuned)


def auto_param_mode(cfg: ModelConfig, mesh) -> str:
    """fsdp when fp32 params per device (TP-only) would exceed ~2 GiB."""
    m = part.axis_size(mesh, "model")
    per_dev = cfg.num_params() * 4 / m
    return "fsdp" if per_dev > 2 * 2**30 else "tp"


def microbatches_for(cfg: ModelConfig, shape: str, mesh) -> int:
    """Bound the remat residual stack (L × B_loc × S × d × 2B) to ~1 GiB.

    Empirically (llama3.2-1b train_4k, 16×16): mb=1 → 14.7 GiB temp,
    mb=4 → 3.9 GiB — the residual stack dominates training memory once
    remat=full and the flash custom-VJP are in place.
    """
    sh = inp.SHAPES[shape]
    if sh.kind != "train":
        return 1
    dp = part.dp_size(mesh)
    b_loc = max(sh.global_batch // dp, 1)
    layers = cfg.num_layers + cfg.num_enc_layers
    resid = layers * b_loc * sh.seq_len * cfg.d_model * 2
    # unshardable heads (whisper/qwen2-vl: 12 H on a 16-way axis) leave
    # attention activations replicated across 'model' — budget tighter
    m = part.axis_size(mesh, "model")
    if cfg.num_heads % m != 0 and cfg.family != "ssm":
        resid *= 4
    mb = 1
    while resid / mb > 1 * 2**30 and mb < b_loc:
        mb *= 2
    return mb


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def _sharding(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               overrides: Optional[Dict[str, Any]] = None,
               donate: bool = True):
    """Lower+compile one (arch × shape × mesh) cell.  Returns (compiled,
    meta dict)."""
    overrides = overrides or {}
    if overrides.get("dp_layout"):
        # §Perf re-mesh experiment: same 256/512 chips, logical axes
        # (data=256, model=1) — pure DP+ZeRO, no TP activation psums.
        import jax as _jax
        mshape = (2, 256, 1) if multi_pod else (256, 1)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        mesh = _jax.make_mesh(mshape, axes)
        mesh_name = ("pod2x256x1" if multi_pod else "pod256x1")
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(len(jax.devices()) if multi_pod else 256)
    cfg = tune_config(get_config(arch), shape, overrides)
    ok, why = inp.shape_applicable(cfg, shape)
    if not ok:
        return None, {"arch": arch, "shape": shape, "mesh": mesh_name,
                      "status": "skip", "reason": why}
    api = build(cfg)
    sh = inp.SHAPES[shape]
    kind = sh.kind

    param_structs = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,),
                                                                  jnp.uint32))
    mode = overrides.get("param_mode") or auto_param_mode(cfg, mesh)
    if mode == "fsdp":
        pspecs = part.zero_shard_specs(cfg, param_structs, mesh)
    else:
        pspecs = part.param_specs(cfg, param_structs, mesh)

    t0 = time.perf_counter()
    if kind == "train":
        mb = int(overrides.get("microbatches") or
                 microbatches_for(cfg, shape, mesh))
        opt_cfg = AdamWConfig()
        grad_specs = None
        if mb > 1 and not overrides.get("no_grad_spec"):
            grad_specs = part.zero_shard_specs(cfg, param_structs, mesh)
        train_step = make_train_step(api, opt_cfg, num_microbatches=mb,
                                     grad_specs=grad_specs)
        state_structs = jax.eval_shape(make_init_fn(api, opt_cfg),
                                       jax.ShapeDtypeStruct((2,), jnp.uint32))
        opt_specs = {
            "m": part.zero_shard_specs(cfg, param_structs, mesh),
            "v": part.zero_shard_specs(cfg, param_structs, mesh),
            "count": P(),
        }
        state_specs = {"params": pspecs, "opt": opt_specs, "step": P()}
        batch_structs = inp.input_specs(cfg, shape)
        batch_specs = part.input_specs_tree(cfg, batch_structs, mesh)
        with mesh, logical_rules(default_rules(cfg, mesh)):
            jitted = jax.jit(
                train_step,
                in_shardings=(_sharding(mesh, state_specs),
                              _sharding(mesh, batch_specs)),
                out_shardings=(_sharding(mesh, state_specs), None),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_structs, batch_structs)
            compiled = lowered.compile()
        extra = {"microbatches": mb}
        tokens = sh.global_batch * sh.seq_len
    elif kind == "prefill":
        serve_params = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
            param_structs)
        cache_structs = jax.eval_shape(
            lambda: api.init_cache(sh.global_batch, sh.seq_len))
        cspecs = part.cache_specs(cfg, cache_structs, mesh)
        batch_structs = inp.input_specs(cfg, shape)
        batch_specs = part.input_specs_tree(cfg, batch_structs, mesh)

        def prefill_step(params, batch, cache):
            return api.prefill(params, batch, cache)

        with mesh, logical_rules(default_rules(cfg, mesh)):
            jitted = jax.jit(
                prefill_step,
                in_shardings=(_sharding(mesh, pspecs),
                              _sharding(mesh, batch_specs),
                              _sharding(mesh, cspecs)),
                out_shardings=(None, _sharding(mesh, cspecs)),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(serve_params, batch_structs,
                                   cache_structs)
            compiled = lowered.compile()
        extra = {}
        tokens = sh.global_batch * sh.seq_len
    else:  # decode
        serve_params = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
            param_structs)
        cache_structs = jax.eval_shape(
            lambda: api.init_cache(sh.global_batch, sh.seq_len))
        cspecs = part.cache_specs(cfg, cache_structs, mesh)
        tok_struct = jax.ShapeDtypeStruct((sh.global_batch, 1), jnp.int32)
        tok_spec = (P(part.batch_axes(mesh), None)
                    if sh.global_batch % part.dp_size(mesh) == 0 else P())

        def decode(params, tokens, cache):
            return api.decode_step(params, tokens, cache)

        with mesh, logical_rules(default_rules(cfg, mesh)):
            jitted = jax.jit(
                decode,
                in_shardings=(_sharding(mesh, pspecs_bf16(pspecs)),
                              NamedSharding(mesh, tok_spec),
                              _sharding(mesh, cspecs)),
                out_shardings=(None, _sharding(mesh, cspecs)),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(serve_params, tok_struct, cache_structs)
            compiled = lowered.compile()
        extra = {}
        tokens = sh.global_batch * 1

    compile_s = time.perf_counter() - t0
    mflops = model_flops(cfg, tokens, kind)
    terms = analyze_compiled(compiled, arch, shape, mesh_name, chips, mflops)
    from repro.roofline.hlo import cpu_widening_artifact_bytes
    artifact = cpu_widening_artifact_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    temp = getattr(mem, "temp_size_in_bytes", 0) or 0
    args_b = getattr(mem, "argument_size_in_bytes", 0) or 0
    meta = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "kind": kind, "chips": chips, "compile_s": round(compile_s, 1),
        "param_mode": mode,
        "tokens": tokens,
        "memory": {
            "argument_bytes": args_b,
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": temp,
            # CPU backend widens scan-carried bf16 buffers to f32 (no
            # native bf16); the TPU executable keeps them bf16.  The
            # TPU-corrected peak removes those f32 twins.
            "cpu_widening_artifact_bytes": artifact,
            "peak_bytes": temp + args_b,
            "tpu_peak_bytes": temp + args_b - artifact,
        },
        "roofline": terms.to_dict(),
        **extra,
    }
    return compiled, meta


def pspecs_bf16(pspecs):
    return pspecs     # specs are dtype-independent; hook kept for clarity


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cells(archs, shapes, multi_pod: bool, out_dir: str,
              overrides: Optional[Dict[str, Any]] = None,
              tag: str = "") -> int:
    os.makedirs(out_dir, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            suffix = f"-{tag}" if tag else ""
            path = os.path.join(
                out_dir, f"{arch}--{shape}--{mesh_name}{suffix}.json")
            if os.path.exists(path):
                print(f"[dryrun] SKIP (cached) {path}")
                continue
            print(f"[dryrun] {arch} × {shape} × {mesh_name} ...",
                  flush=True)
            try:
                compiled, meta = lower_cell(arch, shape,
                                            multi_pod=multi_pod,
                                            overrides=overrides)
                if meta["status"] == "ok":
                    mem = meta["memory"]
                    print(f"  compiled in {meta['compile_s']}s; "
                          f"args={_gb(mem['argument_bytes'])} "
                          f"temp={_gb(mem['temp_bytes'])} "
                          f"dominant={meta['roofline']['dominant']}")
                else:
                    print(f"  SKIP: {meta['reason']}")
            except Exception as e:  # noqa: BLE001
                failures += 1
                meta = {"arch": arch, "shape": shape,
                        "mesh": mesh_name, "status": "fail",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc(limit=8)}
                print(f"  FAIL: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(meta, f, indent=2, default=str)
    return failures


def _gb(x) -> str:
    return "n/a" if x is None else f"{x / 2**30:.2f}GiB"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="dryrun")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--tag", default="", help="suffix for experiment files")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. remat=none)")
    args = ap.parse_args(argv)
    archs = list(list_archs()) if args.arch == "all" else args.arch.split(",")
    shapes = (list(inp.SHAPES) if args.shape == "all"
              else args.shape.split(","))
    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    return run_cells(archs, shapes, args.multi_pod, args.out,
                     overrides=overrides, tag=args.tag)


if __name__ == "__main__":
    sys.exit(main())
