"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model input.

The four LM shapes from the brief; ``kind`` selects which step gets lowered:
  * train   → train_step(state, batch)
  * prefill → prefill(params, batch, cache)
  * decode  → decode_step(params, tokens, cache)   (one token, full cache)

``input_specs(cfg, shape)`` builds weak-type-correct, shardable
ShapeDtypeStructs — no device allocation ever happens for full-size configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic families (brief-mandated skip)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k skipped: pure full-attention architecture "
                       "(quadratic prefill at 524k); run only for "
                       "SSM/hybrid per the brief")
    return True, ""


def token_batch_structs(cfg: ModelConfig, batch: int, seq: int,
                        with_labels: bool) -> Dict[str, Any]:
    i32 = jnp.int32
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if cfg.family == "vlm":
        f32 = jnp.float32
        out["vision_embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                    f32)
        out["vision_mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.bool_)
        out["positions"] = jax.ShapeDtypeStruct((3, batch, seq), i32)
    if cfg.family in ("audio", "encdec"):
        out["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model),
                                             jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Structs for the *batch* of the given shape (train/prefill kinds)."""
    sh = SHAPES[shape_name]
    if sh.kind == "train":
        return token_batch_structs(cfg, sh.global_batch, sh.seq_len,
                                   with_labels=True)
    if sh.kind == "prefill":
        return token_batch_structs(cfg, sh.global_batch, sh.seq_len,
                                   with_labels=False)
    # decode: tokens are [B,1]; the cache is built separately
    return {"tokens": jax.ShapeDtypeStruct((sh.global_batch, 1), jnp.int32)}


def cache_structs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """eval_shape of init_cache for decode shapes (no allocation)."""
    from repro.models.api import build
    sh = SHAPES[shape_name]
    api = build(cfg)
    return jax.eval_shape(
        lambda: api.init_cache(sh.global_batch, sh.seq_len))


def concrete_batch(cfg: ModelConfig, shape_name: str, key=None,
                   batch_override: Optional[int] = None,
                   seq_override: Optional[int] = None) -> Dict[str, Any]:
    """Small concrete batch for smoke tests / examples (CPU-size)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    sh = SHAPES[shape_name]
    B = batch_override or sh.global_batch
    S = seq_override or sh.seq_len
    structs = token_batch_structs(cfg, B, S, with_labels=(sh.kind == "train"))

    def make(k, s):
        if s.dtype == jnp.int32:
            return jax.random.randint(k, s.shape, 0, cfg.vocab_size, s.dtype)
        if s.dtype == jnp.bool_:
            return jnp.zeros(s.shape, s.dtype)
        return jax.random.normal(k, s.shape, s.dtype) * 0.02

    keys = jax.random.split(key, len(structs))
    out = {name: make(k, s)
           for (name, s), k in zip(sorted(structs.items()), keys)}
    if "positions" in out:
        B_, S_ = out["tokens"].shape
        out["positions"] = jnp.broadcast_to(
            jnp.arange(S_, dtype=jnp.int32)[None, None], (3, B_, S_))
    return out
