"""Gradient compression with error feedback — cross-pod DCN relief.

At 512+ chips the 'pod' axis all-reduce crosses DCN (~25 GB/s/host vs
~200 GB/s aggregate ICI), so compressing the cross-pod gradient traffic is
one of the standard large-scale tricks.  Two codecs:

  * bf16: cast-before-reduce (2x), error-free in practice for gradients
    feeding an fp32 optimizer;
  * int8: per-block affine quantization (4x vs fp32) with **error
    feedback** — the quantization residual is carried into the next step's
    gradient, so the *accumulated* update is unbiased (Seide et al. / EF14
    style; contraction property tested with hypothesis in
    tests/test_compression.py).

Codecs are pure functions on pytrees so they compose with pjit: compress →
psum → decompress inside the step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def bf16_compress(tree):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.bfloat16) if g.dtype == jnp.float32 else g,
        tree)


def bf16_decompress(tree):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) if g.dtype == jnp.bfloat16 else g,
        tree)


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def int8_quantize(x: jax.Array) -> Dict[str, jax.Array]:
    """Per-block symmetric int8: q = round(x / s), s = max|x| / 127."""
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32),
            "pad": jnp.asarray(pad, jnp.int32)}


def int8_dequantize(packed: Dict[str, jax.Array], shape, dtype=jnp.float32
                    ) -> jax.Array:
    flat = (packed["q"].astype(jnp.float32) * packed["scale"]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def ef_compress_tree(grads, error_state: Optional[Any] = None):
    """Error-feedback int8 compression over a gradient pytree.

    Returns (packed_tree, new_error_state).  The caller psums ``q``
    (int8 sums fit int32 — we keep int8 end-to-end by averaging AFTER
    dequantize, which psum of q/scale pairs approximates; here we expose
    the codec and the trainer chooses where the reduce happens).
    """
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def comp(g, e):
        corrected = g.astype(jnp.float32) + e
        packed = int8_quantize(corrected)
        decoded = int8_dequantize(packed, g.shape)
        new_e = corrected - decoded        # residual carried forward
        return packed, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_state)
    packed, errs = zip(*[comp(g, e) for g, e in zip(flat_g, flat_e)])
    return (jax.tree_util.tree_unflatten(treedef, list(packed)),
            jax.tree_util.tree_unflatten(treedef, list(errs)))


def ef_decompress_tree(packed_tree, shapes_tree):
    return jax.tree_util.tree_map(
        lambda p, s: int8_dequantize(p, s.shape),
        packed_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, dict) and "q" in x)
