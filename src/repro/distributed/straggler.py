"""Straggler detection & mitigation hooks.

At thousand-node scale, step time is gated by the slowest host.  This
watchdog implements the standard two-stage response:

  1. detect — per-step wall times per host, flag hosts whose EMA exceeds
     ``threshold`` × the cohort median for ``patience`` consecutive steps;
  2. mitigate — report → (operator/orchestrator) either reshards data away
     from the host (``DataReassigner``: shrink its slice of the global
     batch by re-slicing, a pure re-indexing of the deterministic
     pipeline) or evicts it and triggers the elastic-restart path
     (checkpoint → new mesh → restore_resharded).

On this container host_count=1; the logic is exercised in tests by feeding
synthetic timing traces (the detection code path is the real one).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.logging import get_logger

log = get_logger("straggler")


@dataclass
class StragglerConfig:
    threshold: float = 1.5        # × median EMA
    patience: int = 5
    ema: float = 0.9


class StragglerWatchdog:
    def __init__(self, num_hosts: int, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg or StragglerConfig()
        self.num_hosts = num_hosts
        self._ema = np.zeros(num_hosts)
        self._strikes = np.zeros(num_hosts, np.int32)
        self._flagged: List[int] = []

    def record_step(self, host_times: np.ndarray) -> List[int]:
        """Feed per-host step seconds; returns hosts newly flagged."""
        a = self.cfg.ema
        first = self._ema.sum() == 0
        self._ema = host_times if first else a * self._ema + (1 - a) * host_times
        med = np.median(self._ema)
        slow = self._ema > self.cfg.threshold * med
        self._strikes = np.where(slow, self._strikes + 1, 0)
        newly = [int(h) for h in np.nonzero(
            self._strikes == self.cfg.patience)[0]
            if h not in self._flagged]
        for h in newly:
            self._flagged.append(h)
            log.warning("host %d flagged as straggler "
                        "(ema %.3fs vs median %.3fs)", h, self._ema[h], med)
        return newly

    @property
    def flagged(self) -> List[int]:
        return list(self._flagged)

    def clear(self, host: int) -> None:
        if host in self._flagged:
            self._flagged.remove(host)
            self._strikes[host] = 0


class DataReassigner:
    """Shrink flagged hosts' share of the global batch (work stealing).

    The deterministic pipeline makes this a pure re-indexing: host h's
    slice of batch i is (offset[h], offset[h+1]); reassignment just edits
    the offsets — no data movement, no state.
    """

    def __init__(self, global_batch: int, num_hosts: int):
        self.global_batch = global_batch
        self.num_hosts = num_hosts
        self.weights = np.ones(num_hosts)

    def derate(self, host: int, factor: float = 0.5) -> None:
        self.weights[host] *= factor

    def offsets(self) -> np.ndarray:
        w = self.weights / self.weights.sum()
        raw = np.floor(np.cumsum(np.concatenate([[0.0], w]))
                       * self.global_batch).astype(int)
        raw[-1] = self.global_batch
        return raw

    def slice_for(self, host: int) -> slice:
        off = self.offsets()
        return slice(int(off[host]), int(off[host + 1]))
