"""Logical-axis sharding constraints (MaxText-style) for layer internals.

Model code annotates activations with *logical* axes ("batch", "heads",
"ff", ...); the launch layer binds logical→mesh rules for the (config,
mesh) pair before tracing.  With no rules bound (unit tests, single-CPU
smoke runs) every constraint is a no-op, so model code stays mesh-agnostic.

This resolves SPMD propagation ambiguities explicitly — e.g. GQA reshapes
where XLA cannot know whether 'model' should land on the kv-head or the
q-group dim — instead of hoping the partitioner guesses well.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _rules() -> Optional[Dict[str, Axis]]:
    return getattr(_state, "rules", None)


def default_rules(cfg, mesh) -> Dict[str, Axis]:
    """Bind logical axes to mesh axes with divisibility guards."""
    m = mesh.shape.get("model", 1)
    batch = ("pod", "data") if "pod" in mesh.shape else ("data",)
    heads_ok = cfg.num_heads % m == 0
    kv_ok = cfg.num_kv_heads % m == 0
    return {
        "mesh": mesh,                  # consumed by shard_map layers
        "batch": batch,
        "seq": None,
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "head_dim": None,
        "ff": "model" if (cfg.d_ff == 0 or cfg.d_ff % m == 0) else None,
        "moe_ff": "model" if (cfg.moe_d_ff or cfg.d_ff) % max(m, 1) == 0 else None,
        "experts": "model" if (cfg.moe_num_experts % m == 0
                               if cfg.moe_num_experts else False) else None,
        "inner": "model" if (cfg.ssm_d_inner % m == 0
                             if cfg.ssm_state else False) else None,
        "ssm_heads": "model" if (cfg.ssm_heads % m == 0
                                 if cfg.ssm_state else False) else None,
        "embed": None,       # d_model of activations stays unsharded
        "vocab": "model" if cfg.vocab_size % m == 0 else None,
    }


@contextlib.contextmanager
def logical_rules(rules: Dict[str, Axis]):
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint per bound rules (no-op when unbound)."""
    rules = _rules()
    if rules is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = P(*[rules.get(a) if a else None for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


def active_rules() -> Optional[Dict[str, Axis]]:
    """The currently-bound rules (None outside a logical_rules context)."""
    return _rules()
