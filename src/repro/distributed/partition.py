"""Partition rules: map every parameter/activation/cache leaf to a
PartitionSpec on the (pod, data, model) production mesh.

Strategy (Megatron-style TP × DP, EP for MoE, sequence-sharding for caches):

  * batch dims           → ('pod','data') (DP; pod composes hierarchically)
  * attention q/o        → heads on 'model' when H % model == 0, else
                           replicated (whisper/qwen2-vl have 12 heads on a
                           16-way axis; attention then parallelizes over
                           batch only — recorded as waste in §Roofline)
  * attention k/v        → 'model' when K % model == 0 (MHA-ish configs),
                           else replicated (GQA kv-head replication — the
                           standard Megatron treatment when TP > kv_heads)
  * MLP ff dim           → 'model'
  * MoE expert dim       → 'model' (EP: 64/16 = 4 experts per device)
  * Mamba d_inner/heads  → 'model' (SSD heads are embarrassingly parallel)
  * embeddings           → vocab on 'model' when divisible, else d_model
  * KV cache             → kv-heads on 'model' when divisible, else
                           *sequence* on 'model' (flash-decode style); batch
                           on ('pod','data')
  * SSM state            → heads on 'model', batch on DP
  * optimizer state      → param spec + 'data' on the largest free dim
                           (ZeRO-1 style; see zero_shard_specs)

All rules check divisibility against the actual mesh shape and fall back to
replication — a config can never fail to shard, it can only shard worse
(visible in the roofline, never a crash).
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)

def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))

def batch_spec(mesh: Mesh, ndim: int, batch_dim: int = 0) -> P:
    spec = [None] * ndim
    spec[batch_dim] = batch_axes(mesh)
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _spec_with(ndim: int, dim: int, axis: str) -> P:
    spec: list = [None] * ndim
    spec[dim % ndim] = axis
    return P(*spec)


def param_rule(cfg, name: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """One leaf → PartitionSpec.  ``name`` is the '/'-joined tree path."""
    m = axis_size(mesh, "model")
    nd = len(shape)
    leaf = name.rsplit("/", 1)[-1]
    H, K = cfg.num_heads, cfg.num_kv_heads
    heads_ok = H % m == 0
    kv_ok = K % m == 0
    div = lambda dim: shape[dim % nd] % m == 0

    if "embed" in name and leaf == "table":
        if div(-2):                      # vocab
            return _spec_with(nd, -2, "model")
        # non-divisible vocab (whisper 51865, mamba2 50280): replicate.
        # Sharding d_model instead trips the SPMD partitioner on the
        # token-gather inside the microbatch loop (observed: whisper
        # train_4k, "slice dim size 768 > dynamic slice dimension 48").
        return P()
    if leaf == "pos_embed" or "pos_embed" in name:
        # replicated: d-sharding here propagates onto the token-embedding
        # gather (x = embed + pos_embed) and trips the SPMD partitioner
        return P()

    # attention
    if leaf == "wq":
        return _spec_with(nd, -1, "model") if heads_ok and div(-1) else P()
    if leaf in ("wk", "wv"):
        return _spec_with(nd, -1, "model") if kv_ok and div(-1) else P()
    if leaf == "wo":
        return _spec_with(nd, -2, "model") if heads_ok and div(-2) else P()

    # MoE: expert dim is always third-from-last ([.., E, d, f] / [.., E, f, d])
    if ("/moe" in name or name.startswith("moe")) and "shared" not in name:
        if leaf == "router":
            return P()
        if leaf in ("w_up", "w_gate", "w_down") and nd >= 3:
            E = shape[-3]
            if E % m == 0:
                return _spec_with(nd, -3, "model")
            return _spec_with(nd, -1, "model") if div(-1) else P()
        # shared-expert MLP falls through to the dense rules below

    # dense MLP
    if leaf in ("w_up", "w_gate"):
        return _spec_with(nd, -1, "model") if div(-1) else P()
    if leaf == "w_down":
        return _spec_with(nd, -2, "model") if div(-2) else P()

    # mamba2
    if leaf in ("w_z", "w_x"):
        return _spec_with(nd, -1, "model") if div(-1) else P()
    if leaf in ("w_B", "w_C", "conv_B", "conv_C"):
        return P()
    if leaf == "w_dt":
        return _spec_with(nd, -1, "model") if div(-1) else P()
    if leaf == "conv_x":
        return _spec_with(nd, -1, "model") if div(-1) else P()
    if leaf in ("A_log", "D", "dt_bias"):
        return _spec_with(nd, -1, "model") if div(-1) else P()
    if leaf == "out_proj":
        return _spec_with(nd, -2, "model") if div(-2) else P()
    if "mamba" in name and leaf == "scale":     # gated-norm over d_inner
        return _spec_with(nd, -1, "model") if div(-1) else P()

    # norms / scalars / anything small
    return P()


def param_specs(cfg, params_tree, mesh: Mesh):
    """Tree of PartitionSpec matching a params (or eval_shape) tree."""
    def rule(path, leaf):
        return param_rule(cfg, _path_str(path), tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(rule, params_tree)


# ---------------------------------------------------------------------------
# optimizer-state rules (ZeRO-1 style)
# ---------------------------------------------------------------------------

def zero_shard_specs(cfg, params_tree, mesh: Mesh, axis: str = "data"):
    """Param spec + ``axis`` on the largest still-unsharded divisible dim.

    Applied to AdamW m/v (and optionally fp32 masters): optimizer state is
    additionally sharded over the data axis, cutting its per-device memory
    by |data| — the ZeRO-1 trick, expressed purely as shardings.
    """
    d = axis_size(mesh, axis)

    def rule(path, leaf):
        spec = list(param_rule(cfg, _path_str(path), tuple(leaf.shape), mesh))
        spec += [None] * (len(leaf.shape) - len(spec))
        best, best_size = None, 0
        for i, s in enumerate(spec):
            if s is None and leaf.shape[i] % d == 0 and leaf.shape[i] > best_size:
                best, best_size = i, leaf.shape[i]
        if best is not None and best_size > 1:
            spec[best] = axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


# ---------------------------------------------------------------------------
# activation / input / cache rules
# ---------------------------------------------------------------------------

def input_specs_tree(cfg, batch_tree, mesh: Mesh):
    """Shardings for a training/prefill input batch (by leaf name)."""
    b = batch_axes(mesh)
    dp = dp_size(mesh)

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name == "positions" and nd == 3:      # M-RoPE [3,B,S]
            return P(None, b, None) if leaf.shape[1] % dp == 0 else P()
        if nd == 0 or leaf.shape[0] % dp != 0:   # e.g. batch=1 long-context
            return P(*([None] * nd))
        return P(b, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_specs(cfg, cache_tree, mesh: Mesh):
    """Shardings for a KV/SSM cache tree (see module docstring)."""
    m = axis_size(mesh, "model")
    dp = dp_size(mesh)
    b = batch_axes(mesh)
    kv_ok = cfg.num_kv_heads % m == 0

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:                                        # pos scalar
            return P()
        if name.rsplit("/", 1)[-1] in ("k", "v", "xk", "xv"):
            # [L, B, S, K, hd] (dense/encdec) or [nb, B, S, K, hd] (hybrid)
            bax = b if leaf.shape[1] % dp == 0 else None
            if kv_ok:
                return P(None, bax, None, "model", None)
            if leaf.shape[2] % m == 0:
                return P(None, bax, "model", None, None)   # sequence shard
            return P(None, bax, None, None, None)
        if "state" in name:
            # [L, B, H, P, N] or [nb, n_ssm, B, H, P, N]
            hdim = nd - 3
            spec = [None] * nd
            if leaf.shape[hdim - 1] % dp == 0:
                spec[hdim - 1] = b
            if leaf.shape[hdim] % m == 0:
                spec[hdim] = "model"
            return P(*spec)
        if "conv" in name:
            # [L, B, k-1, C] or [nb, n_ssm, B, k-1, C]
            spec = [None] * nd
            if leaf.shape[nd - 3] % dp == 0:
                spec[nd - 3] = b
            if leaf.shape[-1] % m == 0 and leaf.shape[-1] >= m:
                spec[-1] = "model"
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def shardings_of(specs_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, P))
