"""repro.distributed — mesh-aware sharding, compression, fault tolerance."""
from .partition import (batch_axes, batch_spec, cache_specs, input_specs_tree,
                        param_specs, zero_shard_specs)

__all__ = ["batch_axes", "batch_spec", "cache_specs", "input_specs_tree",
           "param_specs", "zero_shard_specs"]
