"""llama3.2-1b — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256; tied embeddings,
rope_theta=500000 (llama3 convention), head_dim 64.
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
    norm_eps=1e-5,
))
