"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=1536 attention-free, vocab 50280, ssm_state=128; expand=2 →
d_inner=3072, head_dim 64 → 48 SSD heads, 1 group, conv4.  Sub-quadratic:
runs the long_500k shape.
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv=4,
    ssm_groups=1,
))
