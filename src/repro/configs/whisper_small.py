"""whisper-small — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

12L d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865.  12 encoder +
12 decoder layers; LayerNorm + GELU, learned decoder positions, sinusoidal
encoder positions.  The mel/conv frontend is a STUB: input_specs supplies
precomputed frame embeddings [B, 1500, d].  Attention biases of the
upstream checkpoint are omitted (systems-level reproduction; noted in
DESIGN.md).
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    num_enc_layers=12,
    enc_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    tie_embeddings=True,
    use_rope=False,
    learned_pos=True,
    norm="layernorm",
    act="gelu",
    norm_eps=1e-5,
    frontend="audio_frames",
    max_seq=32768,
))
