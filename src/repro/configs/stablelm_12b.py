"""stablelm-12b [hf:stabilityai/stablelm-2-12b; hf].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352; head_dim 160
(d_model/H; not MXU-128-aligned — a deliberate roofline stressor, see
EXPERIMENTS.md §Roofline).
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    rope_theta=10000.0,
))
