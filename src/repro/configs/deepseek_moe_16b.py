"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066; hf].

28L d_model=2048 16H (GQA kv=16) vocab=102400; 2 shared + 64 routed
experts, top-6, per-expert d_ff=1408 (fine-grained expert segmentation).
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    moe_d_ff=1408,
    rope_theta=10000.0,
))
