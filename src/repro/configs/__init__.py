"""Assigned architecture configs — one module per arch (import registers).

Every config carries the exact figures from the assignment brief; deviations
forced by implementation realities are commented inline and summarized in
DESIGN.md §Arch-applicability.
"""
from . import (deepseek_moe_16b, internlm2_1_8b, jamba_v0_1_52b,
               llama3_2_1b, mamba2_780m, moonshot_v1_16b_a3b, qwen2_vl_2b,
               qwen3_1_7b, stablelm_12b, whisper_small)

__all__ = ["deepseek_moe_16b", "internlm2_1_8b", "jamba_v0_1_52b",
           "llama3_2_1b", "mamba2_780m", "moonshot_v1_16b_a3b",
           "qwen2_vl_2b", "qwen3_1_7b", "stablelm_12b", "whisper_small"]
