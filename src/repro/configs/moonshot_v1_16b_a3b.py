"""moonshot-v1-16b-a3b (kimi/moonlight) [hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) vocab=163840; MoE: 64 routed experts,
top-6, per-expert d_ff=1408 (fine-grained).  The brief lists exactly these
figures; every layer is MoE (no shared experts are listed, so none are
instantiated — deviation from upstream Moonlight's 2 shared experts is
noted in DESIGN.md).
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=0,
    moe_d_ff=1408,
    rope_theta=50000.0,
))
