"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; MoE 16 experts
top-2 on every other layer.  Period-8 superblocks: attention at block
index 4, Mamba elsewhere (1:7); no positional encoding (use_rope=False).
Jamba v0.1 uses Mamba-1 layers; we implement the Mamba-2/SSD block (same
state budget: ssm_state=16, d_inner=2*d, conv4) — deviation recorded in
DESIGN.md.  Sub-quadratic: runs the long_500k shape.
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    use_rope=False,
    moe_num_experts=16,
    moe_top_k=2,
    moe_every=2,
    moe_offset=1,
    moe_d_ff=14336,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv=4,
    ssm_groups=1,
))
