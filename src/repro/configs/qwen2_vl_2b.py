"""qwen2-vl-2b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The vision tower
is a STUB per the brief: input_specs supplies precomputed patch embeddings
(vision_embeds + vision_mask) merged into the token stream; M-RoPE rotates
q/k with three position streams (t,h,w) split 24/20/20 over head_dim/2=64.
"""
from repro.models.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    tie_embeddings=True,
    rope_theta=1000000.0,
    mrope_sections=(24, 20, 20),
    frontend="vision_patches",
))
