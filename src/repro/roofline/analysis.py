"""Three-term roofline from a compiled dry-run artifact (TPU v5e model).

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute instruction.  The dominant term is the bottleneck the
§Perf loop iterates on.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Any, Dict

from repro.core.sysinfo import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8,
    "s64": 8, "u64": 8, "f64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape literal: bf16[128,4096]{1,0} or f32[] — capture dtype + dims
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# an HLO instruction line: "%name = <shape(s)> opcode(...operands...)"
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" +
    "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text.

    ``-start``/``-done`` async pairs are counted once (on -start; the -done
    line carries no operand shapes of its own in the same form, but guard by
    skipping lines with '-done(' anyway).
    """
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind, operands = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(operands))
        if nbytes == 0:
            continue
        per_kind[kind] += nbytes
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind_bytes": per_kind,
            "per_kind_count": counts}


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes_: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float              # MODEL_FLOPS / HLO_FLOPs
    bytes_per_device: float = 0.0
    notes: str = ""
    per_kind: Dict[str, int] = field(default_factory=dict)

    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def model_flops(cfg, tokens: int, kind: str = "train") -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); 2·N·D for inference kinds."""
    n = cfg.num_active_params()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def roofline_terms(arch: str, shape: str, mesh_name: str, chips: int,
                   flops: float, bytes_accessed: float, coll_bytes: float,
                   mflops: float, bytes_per_device: float = 0.0,
                   notes: str = "", per_kind=None) -> RooflineTerms:
    """``flops``/``bytes_accessed``/``coll_bytes`` are PER-DEVICE (the
    post-SPMD HLO module is the per-device program), so the brief's
    ``X / (chips × rate)`` denominators reduce to ``X / rate`` here —
    global = per-device × chips throughout."""
    hw = TPU_V5E
    compute_s = flops / hw["peak_bf16_flops"]
    memory_s = bytes_accessed / hw["hbm_bandwidth"]
    collective_s = coll_bytes / hw["ici_link_bandwidth"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        collective_bytes_=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mflops,
        useful_ratio=(mflops / (flops * chips) if flops else 0.0),
        bytes_per_device=bytes_per_device, notes=notes,
        per_kind=per_kind or {})


def analyze_compiled(compiled, arch: str, shape: str, mesh_name: str,
                     chips: int, mflops: float,
                     notes: str = "") -> RooflineTerms:
    """Full analysis of a jax ``Compiled`` object.

    Uses the loop-aware HLO analyzer (repro.roofline.hlo) — XLA's own
    cost_analysis counts scan bodies once, undercounting scan-over-layers
    models by ~num_layers×.
    """
    from .hlo import analyze_hlo
    st = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    bpd = 0.0
    if mem is not None:
        bpd = (getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "temp_size_in_bytes", 0))
    return roofline_terms(arch, shape, mesh_name, chips, st.flops,
                          st.bytes_accessed, st.collective_bytes, mflops,
                          bytes_per_device=bpd, notes=notes,
                          per_kind=dict(st.per_kind_bytes))
