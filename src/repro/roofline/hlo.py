"""Static analyzer for optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — our models
are scan-over-layers (and flash attention is a scan over chunk pairs), so
its FLOPs undercount by ~L×pairs.  This module re-derives per-device
FLOPs / HBM-bytes / collective-bytes from the HLO text itself, with loop
trip-count multipliers:

  * computations are parsed into per-computation symbol tables
    (instruction name → shape), so operand shapes resolve exactly;
  * ``while`` trip counts come from the integer constant in the loop
    condition's ``compare``;
  * FLOPs: 2·prod(out)·prod(contracting dims) per ``dot`` (+convolutions),
    walked through calls/fusions/whiles with multipliers;
  * HBM bytes: Σ (output + operands) over *top-level* instructions of
    non-fusion computations — fusion nodes count as single ops, which
    approximates post-fusion buffer traffic (a roofline-style
    no-cache-reuse estimate);
  * collective bytes: Σ operand sizes per collective op × loop multiplier
    (the brief's definition), with per-kind breakdown.

Validated against hand-computed counts in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) +
    r")\[([\d,]*)\](?:\{[^}]*\})?")

# shape group is lazy-anything: tuple shapes may contain /*index=N*/
# comments (with '='), so the opcode is just the first word followed by '('
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)([\w\-]+)\((.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{$")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


@dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str            # everything after the opening paren

    def operands(self) -> List[str]:
        # operand names: %foo or bare foo.1 tokens before "), attr=..."
        depth, out, cur = 1, [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            cur.append(ch)
        arglist = "".join(cur)
        return re.findall(r"%([\w\.\-]+)", arglist)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=(\{[^}]*\}|\[[^\]]*\]<=\[\d+\]|[\w\.\-%]+)",
                      self.rest)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr] = field(default_factory=dict)

    def shapes(self, name: str) -> List[Tuple[str, int]]:
        """[(dtype, numel)] for an instruction's (possibly tuple) shape."""
        ins = self.instrs.get(name)
        if ins is None:
            return []
        return parse_shape(ins.shape_str)


def parse_shape(s: str) -> List[Tuple[str, int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def shape_bytes(s: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in parse_shape(s))


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape_str, opcode, rest = m.groups()
            cur.instrs[name] = Instr(name, shape_str.strip(), opcode, rest)
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(cond: Computation) -> int:
    """Fallback: largest integer constant in the loop condition."""
    best = 1
    for ins in cond.instrs.values():
        if ins.opcode == "constant":
            m = re.match(r"([\-\d]+)", ins.rest)
            if m:
                try:
                    best = max(best, int(m.group(1)))
                except ValueError:
                    pass
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = sum(n for _, n in parse_shape(ins.shape_str))
    ops = ins.operands()
    contract = 1
    cdims = ins.attr("lhs_contracting_dims")
    lhs_ins = comp.instrs.get(ops[0]) if ops else None
    if cdims and lhs_ins is not None:
        m = _SHAPE_RE.search(lhs_ins.shape_str)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d]
            for di in re.findall(r"\d+", cdims):
                i = int(di)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out_elems * contract


_FLOP_OPS = {"dot"}
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}
_SLICING_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_param_traffic(called: Computation) -> Dict[int, Optional[int]]:
    """Per-parameter HBM traffic of a fusion computation.

    * consumed ONLY by slicing ops (dynamic-slice/slice/gather) → read at
      slice granularity (scan bodies slicing one layer from the stack);
    * consumed ONLY as the in-place target (operand 0) of
      dynamic-update-slice → 0 (aliased; the written region is counted by
      the fusion-output rule);
    * otherwise → None = full parameter shape.
    """
    out: Dict[int, Optional[int]] = {}
    params: Dict[str, int] = {}
    for ins in called.instrs.values():
        if ins.opcode == "parameter":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    consumers: Dict[str, List[Tuple[Instr, int]]] = {}
    for ins in called.instrs.values():
        for pos, o in enumerate(ins.operands()):
            if o in params:
                consumers.setdefault(o, []).append((ins, pos))
    for pname, idx in params.items():
        cons = consumers.get(pname, [])
        # slice reads + in-place DUS targets: count only the touched
        # regions (the read-modify-write accumulator pattern of the flash
        # pair scan: dynamic-slice(acc) ... dynamic-update-slice(acc,...))
        if cons and all(c.opcode in _SLICING_OPS
                        or (c.opcode == "dynamic-update-slice" and pos == 0)
                        for c, pos in cons):
            out[idx] = sum(shape_bytes(c.shape_str) for c, pos in cons
                           if c.opcode in _SLICING_OPS)
        else:
            out[idx] = None
    return out


def _fusion_out_bytes(called: Computation, default: int) -> int:
    """Fusion output traffic: a fusion whose result is dynamic-update-slice
    writes only the updated region (the rest is aliased) — count 2× the
    update operand per DUS instead of the whole buffer."""
    dus = [ins for ins in called.instrs.values()
           if ins.opcode == "dynamic-update-slice"]
    if not dus:
        return default
    total = 0
    for ins in dus:
        ops = ins.operands()
        if len(ops) >= 2:
            total += 2 * sum(_DTYPE_BYTES[dt] * n
                             for dt, n in called.shapes(ops[1]))
    return total if total else default


def cpu_widening_artifact_bytes(text: str) -> int:
    """Bytes of CPU-only bf16→f32 loop-buffer widening.

    The CPU backend has no native bf16 compute: scan-carried bf16 buffers
    get an f32 twin inside while tuples ("wide" legalization).  On the TPU
    target these buffers stay bf16, so the f32 twin's full size is memory
    the TPU executable does not allocate.  Detected as f32 while-tuple
    elements whose dims exactly match a bf16 sibling.
    """
    comps = parse_module(text)
    artifact = 0
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode != "while":
                continue
            dims_bf16 = set()
            elems = _SHAPE_RE.findall(ins.shape_str)
            for dt, dims in elems:
                if dt == "bf16":
                    dims_bf16.add(dims)
            for dt, dims in elems:
                if dt == "f32" and dims in dims_bf16:
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    artifact += 4 * n
    return artifact


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    per_kind_bytes: Dict[str, float] = field(default_factory=dict)
    per_kind_count: Dict[str, int] = field(default_factory=dict)
    loop_trips: Dict[str, int] = field(default_factory=dict)


def analyze_hlo(text: str) -> HloStats:
    comps = parse_module(text)
    stats = HloStats(per_kind_bytes={k: 0.0 for k in _COLLECTIVES},
                     per_kind_count={k: 0 for k in _COLLECTIVES})

    entry = None
    for name, c in comps.items():
        if "main" in name:
            entry = c
            break
    if entry is None and comps:           # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    if entry is None:
        return stats

    visited_flops: set = set()

    def walk(comp: Computation, mult: float, count_bytes: bool):
        for ins in comp.instrs.values():
            op = ins.opcode
            if op == "while":
                body_name = (ins.attr("body") or "").lstrip("%")
                cond_name = (ins.attr("condition") or "").lstrip("%")
                # best source: XLA's own analysis in backend_config
                m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
                if m:
                    trips = int(m.group(1))
                elif cond_name in comps:
                    trips = _trip_count(comps[cond_name])
                else:
                    trips = 1
                stats.loop_trips[body_name] = trips
                if body_name in comps:
                    walk(comps[body_name], mult * trips, count_bytes)
                continue
            if op in ("call", "conditional", "async-start"):
                tgt = (ins.attr("to_apply") or ins.attr("called_computations")
                       or "").lstrip("%")
                if tgt in comps:
                    walk(comps[tgt], mult, count_bytes)
            if op == "fusion":
                tgt = (ins.attr("calls") or "").lstrip("%")
                # descend for FLOPs only (dots inside fusions)
                if tgt in comps:
                    for sub in comps[tgt].instrs.values():
                        if sub.opcode in _FLOP_OPS:
                            stats.flops += mult * _dot_flops(comps[tgt], sub)
            if op in _FLOP_OPS:
                stats.flops += mult * _dot_flops(comp, ins)
            # collectives (sync or -start forms)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                opnd_bytes = 0
                for o in ins.operands():
                    opnd_bytes += sum(_DTYPE_BYTES[dt] * n
                                      for dt, n in comp.shapes(o))
                if opnd_bytes == 0:   # fall back to output size
                    opnd_bytes = shape_bytes(ins.shape_str)
                stats.per_kind_bytes[base] += mult * opnd_bytes
                stats.per_kind_count[base] += int(mult)
                stats.collective_bytes += mult * opnd_bytes
            # bytes accessed (roofline-style, fusion-granular, slice-aware)
            if count_bytes and op not in _SKIP_BYTES_OPS:
                b = shape_bytes(ins.shape_str)
                operands = ins.operands()
                if op in _SLICING_OPS:
                    b *= 2                       # read slice + write out
                elif op == "dynamic-update-slice" and len(operands) >= 2:
                    upd = sum(_DTYPE_BYTES[dt] * n
                              for dt, n in comp.shapes(operands[1]))
                    b = 2 * upd                  # read update + write region
                elif op == "fusion":
                    tgt = (ins.attr("calls") or "").lstrip("%")
                    traffic = (_fusion_param_traffic(comps[tgt])
                               if tgt in comps else {})
                    if tgt in comps:
                        b = _fusion_out_bytes(comps[tgt], b)
                    for i, o in enumerate(operands):
                        t = traffic.get(i)
                        if t is not None:
                            b += t
                        else:
                            b += sum(_DTYPE_BYTES[dt] * n
                                     for dt, n in comp.shapes(o))
                else:
                    for o in operands:
                        b += sum(_DTYPE_BYTES[dt] * n
                                 for dt, n in comp.shapes(o))
                stats.bytes_accessed += mult * b

    walk(entry, 1.0, True)
    return stats
