"""Hybrid roofline: measured HLO traffic with Pallas-kernel substitution.

The XLA-scan flash attention spills every [cq,ck] scores tile to HBM (the
fusion boundaries are HBM round-trips) — ~8-10 passes over Sq·Sk/2 fp32
elements.  The shipped Pallas kernel (repro.kernels.flash_attention) keeps
scores, m, l and the output accumulator in VMEM scratch: its HBM traffic is
only the q/k/v tile streams and the output write.  The kernel cannot be
*compiled* on the CPU backend (interpret mode lowers the body to XLA ops,
reintroducing the same boundaries), so its contribution is ANALYTIC:

  kernel_bytes/device =
      q read (Sq·H·D·eb)                    # streamed once
    + k,v reads (nq · Sk_eff · H · D · eb)  # re-streamed per q block
    + out write (Sq·H·D·eb)
  with Sk_eff = (diag-skip) half of Sk for causal, eb = element bytes.

The pair-scan's measured traffic is identified in the HLO as the while
bodies whose trip counts equal the pair-schedule lengths, and replaced.
Both numbers are reported (§Perf shows XLA-formulation AND kernel-modeled
terms); the substitution is exact in FLOPs (same dots) and conservative in
bytes (ignores VMEM-resident double-buffering wins).
"""
from __future__ import annotations

import re
from typing import Dict, Set, Tuple

from .hlo import (_DTYPE_BYTES, _SKIP_BYTES_OPS, _SLICING_OPS,
                  _fusion_out_bytes, _fusion_param_traffic, parse_module,
                  shape_bytes)


def _region_traffic(comps, entry) -> Dict[str, Tuple[float, float]]:
    """Per-while-body (trip-weighted traffic, trips) from the entry walk."""
    out: Dict[str, Tuple[float, float]] = {}

    def body_bytes(comp) -> float:
        total = 0.0
        for ins in comp.instrs.values():
            op = ins.opcode
            if op in _SKIP_BYTES_OPS or op == "while":
                continue
            operands = ins.operands()
            b = shape_bytes(ins.shape_str)
            if op in _SLICING_OPS:
                b *= 2
            elif op == "dynamic-update-slice" and len(operands) >= 2:
                b = 2 * sum(_DTYPE_BYTES[dt] * n
                            for dt, n in comp.shapes(operands[1]))
            elif op == "fusion":
                tgt = (ins.attr("calls") or "").lstrip("%")
                traffic = (_fusion_param_traffic(comps[tgt])
                           if tgt in comps else {})
                if tgt in comps:
                    b = _fusion_out_bytes(comps[tgt], b)
                for i, o in enumerate(operands):
                    t = traffic.get(i)
                    b += (t if t is not None else
                          sum(_DTYPE_BYTES[dt] * n
                              for dt, n in comp.shapes(o)))
            else:
                for o in operands:
                    b += sum(_DTYPE_BYTES[dt] * n
                             for dt, n in comp.shapes(o))
            total += b
        return total

    def walk(comp, mult):
        for ins in comp.instrs.values():
            if ins.opcode != "while":
                continue
            body = (ins.attr("body") or "").lstrip("%")
            m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
            trips = int(m.group(1)) if m else 1
            if body in comps:
                prev = out.get(body, (0.0, trips))
                out[body] = (prev[0] + mult * trips * body_bytes(comps[body]),
                             trips)
                walk(comps[body], mult * trips)

    walk(entry, 1.0)
    return out


def flash_kernel_bytes(B_loc: int, Sq: int, Sk: int, H_loc: int, D: int,
                       causal: bool, elem_bytes: int = 2,
                       bq: int = 512) -> float:
    """Analytic per-device HBM traffic of the Pallas flash kernel."""
    nq = max(Sq // bq, 1)
    sk_eff = Sk / 2 if causal else Sk
    q_read = B_loc * Sq * H_loc * D * elem_bytes
    kv_read = 2 * B_loc * nq * sk_eff * H_loc * D * elem_bytes
    out_write = B_loc * Sq * H_loc * D * elem_bytes
    return q_read + kv_read + out_write


def attention_pair_trips(Sq: int, Sk: int, cq: int, ck: int) -> Set[int]:
    """Trip counts that identify flash pair-scan while bodies."""
    from repro.models.layers import _chunk_pairs, _split_pairs
    trips = set()
    pairs = _chunk_pairs(Sq, Sk, min(cq, Sq), min(ck, Sk), True, True)
    offd, diag = _split_pairs(Sq, Sk, min(cq, Sq), min(ck, Sk), True, True)
    for t in (len(pairs), len(offd), len(diag)):
        if t > 1:
            trips.add(t)
    full = (Sq // min(cq, Sq)) * (Sk // min(ck, Sk))
    if full > 1:
        trips.add(full)           # non-causal/unsplit schedules
    return trips


def adjust_memory_term(compiled_text: str, pair_trips: Set[int],
                       kernel_bytes: float) -> Dict[str, float]:
    """(measured_total, pair_scan_bytes, adjusted_total)."""
    comps = parse_module(compiled_text)
    entry = None
    for name, c in comps.items():
        if "main" in name:
            entry = c
            break
    if entry is None:
        return {}
    regions = _region_traffic(comps, entry)
    pair_bytes = sum(b for name, (b, trips) in regions.items()
                     if trips in pair_trips)
    from .hlo import analyze_hlo
    st = analyze_hlo(compiled_text)
    return {
        "measured_bytes": st.bytes_accessed,
        "pair_scan_bytes": pair_bytes,
        "kernel_bytes": kernel_bytes,
        "adjusted_bytes": st.bytes_accessed - pair_bytes + kernel_bytes,
    }
