"""repro.roofline — static performance analysis of compiled XLA artifacts."""
from .analysis import (RooflineTerms, analyze_compiled, collective_bytes,
                       model_flops, roofline_terms)

__all__ = ["RooflineTerms", "analyze_compiled", "collective_bytes",
           "model_flops", "roofline_terms"]
