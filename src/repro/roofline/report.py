"""Markdown report generation from dry-run cell JSONs (EXPERIMENTS.md feed).

``python -m repro.roofline.report [--dir results/dryrun] [--mesh pod16x16]``
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

ARCH_ORDER = ["llama3.2-1b", "qwen3-1.7b", "internlm2-1.8b", "stablelm-12b",
              "qwen2-vl-2b", "moonshot-v1-16b-a3b", "deepseek-moe-16b",
              "mamba2-780m", "jamba-v0.1-52b", "whisper-small"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(directory: str, mesh: Optional[str] = None,
               tag: str = "") -> List[Dict[str, Any]]:
    cells = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("--")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    key = lambda d: (ARCH_ORDER.index(d["arch"])
                     if d["arch"] in ARCH_ORDER else 99,
                     SHAPE_ORDER.index(d["shape"])
                     if d["shape"] in SHAPE_ORDER else 99)
    return sorted(cells, key=key)


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(cells: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | status | compute | memory | collective | "
           "dominant | bound | useful (6ND/HLO) | peak GiB (TPU-corr) | "
           "mode/mb |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for d in cells:
        if d["status"] == "skip":
            rows.append(f"| {d['arch']} | {d['shape']} | SKIP | - | - | - "
                        f"| - | - | - | - | - |")
            continue
        if d["status"] != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | FAIL | - | - | - "
                        f"| - | - | - | - | - |")
            continue
        r = d["roofline"]
        m = d["memory"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        peak = m.get("tpu_peak_bytes", m["peak_bytes"]) / 2 ** 30
        mode = d.get("param_mode", "-")
        mb = d.get("microbatches", "")
        rows.append(
            f"| {d['arch']} | {d['shape']} | ok | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {fmt_s(bound)} "
            f"| {r['useful_ratio']:.2f} | {peak:.1f} "
            f"| {mode}{'/' + str(mb) if mb else ''} |")
    return hdr + "\n".join(rows)


def dryrun_table(cells: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | compile s | args GiB | temp GiB | "
           "coll/dev GB | collective mix |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for d in cells:
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        m = d["memory"]
        mix = ", ".join(f"{k.replace('all-', 'a')}:{v / 1e9:.1f}"
                        for k, v in r.get("per_kind", {}).items() if v)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['compile_s']} | {m['argument_bytes'] / 2**30:.2f} "
            f"| {m['temp_bytes'] / 2**30:.2f} "
            f"| {r['collective_bytes_'] / 1e9:.2f} | {mix} |")
    return hdr + "\n".join(rows)


def pick_hillclimb(cells: List[Dict[str, Any]]) -> Dict[str, str]:
    """worst roofline fraction / most collective-bound / most
    representative (full measurement stack: hybrid+MoE+SSM train)."""
    ok = [d for d in cells if d["status"] == "ok"]
    def frac(d):
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        return r["compute_s"] / bound if bound else 0
    worst = min(ok, key=frac)
    coll = max(ok, key=lambda d: d["roofline"]["collective_s"] /
               max(d["roofline"]["compute_s"], 1e-12))
    return {
        "worst_fraction": f"{worst['arch']} × {worst['shape']}",
        "most_collective": f"{coll['arch']} × {coll['shape']}",
        "most_representative": "jamba-v0.1-52b × train_4k",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    cells = load_cells(args.dir, args.mesh, args.tag)
    print("## Roofline table (%s)\n" % args.mesh)
    print(roofline_table(cells))
    print("\n## Dry-run details\n")
    print(dryrun_table(cells))
    print("\n## Hillclimb candidates\n")
    for k, v in pick_hillclimb(cells).items():
        print(f"* {k}: {v}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
