"""Static profiler: top HBM-traffic / FLOPs contributors of a dry-run cell.

The §Perf loop's "profile" on a CPU-only container: ranks instructions by
loop-trip-weighted bytes/flops so the hypothesis targets the actual
dominant op, not a guess.

``python -m repro.roofline.profile --arch X --shape Y [--override k=v]``
"""
from __future__ import annotations

import argparse
import json
import re
from typing import List, Tuple

from .hlo import (_DTYPE_BYTES, _SKIP_BYTES_OPS, _SLICING_OPS, _dot_flops,
                  _fusion_out_bytes, _fusion_param_traffic, parse_module,
                  shape_bytes)


def top_contributors(text: str, n: int = 15):
    comps = parse_module(text)
    entry = None
    for name, c in comps.items():
        if "main" in name:
            entry = c
            break
    if entry is None:
        return [], []
    byte_rows: List[Tuple[float, str, str, str]] = []
    flop_rows: List[Tuple[float, str, str, str]] = []

    def walk(comp, mult):
        for ins in comp.instrs.values():
            op = ins.opcode
            if op == "while":
                body = (ins.attr("body") or "").lstrip("%")
                m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                              ins.rest)
                trips = int(m.group(1)) if m else 1
                if body in comps:
                    walk(comps[body], mult * trips)
                continue
            if op in _SKIP_BYTES_OPS:
                continue
            operands = ins.operands()
            if op == "dot":
                flop_rows.append((mult * _dot_flops(comp, ins), op,
                                  ins.name, comp.name))
            if op == "fusion":
                tgt = (ins.attr("calls") or "").lstrip("%")
                if tgt in comps:
                    for sub in comps[tgt].instrs.values():
                        if sub.opcode == "dot":
                            flop_rows.append(
                                (mult * _dot_flops(comps[tgt], sub),
                                 "dot(fused)", ins.name, comp.name))
            b = shape_bytes(ins.shape_str)
            if op in _SLICING_OPS:
                b *= 2
            elif op == "dynamic-update-slice" and len(operands) >= 2:
                upd = sum(_DTYPE_BYTES[dt] * x
                          for dt, x in comp.shapes(operands[1]))
                b = 2 * upd
            elif op == "fusion":
                tgt = (ins.attr("calls") or "").lstrip("%")
                traffic = (_fusion_param_traffic(comps[tgt])
                           if tgt in comps else {})
                if tgt in comps:
                    b = _fusion_out_bytes(comps[tgt], b)
                for i, o in enumerate(operands):
                    t = traffic.get(i)
                    b += (t if t is not None else
                          sum(_DTYPE_BYTES[dt] * x
                              for dt, x in comp.shapes(o)))
            else:
                for o in operands:
                    b += sum(_DTYPE_BYTES[dt] * x
                             for dt, x in comp.shapes(o))
            byte_rows.append((mult * b, op, ins.name, comp.name))

    walk(entry, 1.0)
    byte_rows.sort(reverse=True)
    flop_rows.sort(reverse=True)
    return byte_rows[:n], flop_rows[:n]


def profile_cell(arch: str, shape: str, overrides=None, multi_pod=False,
                 n: int = 15):
    from repro.launch.dryrun import lower_cell
    compiled, meta = lower_cell(arch, shape, multi_pod=multi_pod,
                                overrides=overrides or {})
    byte_rows, flop_rows = top_contributors(compiled.as_text(), n)
    return meta, byte_rows, flop_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args(argv)
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    meta, byte_rows, flop_rows = profile_cell(args.arch, args.shape,
                                              overrides, n=args.top)
    r = meta["roofline"]
    print(f"terms: compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s"
          f" collective={r['collective_s']:.3f}s dominant={r['dominant']}")
    print("\ntop HBM-traffic contributors (per device):")
    for b, op, name, cn in byte_rows:
        print(f"  {b/1e9:9.1f} GB  {op:22s} {name[:40]:40s} {cn[:40]}")
    print("\ntop FLOPs contributors (per device):")
    for f, op, name, cn in flop_rows:
        print(f"  {f/1e12:9.2f} TF  {op:22s} {name[:40]:40s} {cn[:40]}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
