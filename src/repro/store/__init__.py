"""``repro.store`` — the queryable fleet result store.

``results/history.jsonl`` (:mod:`repro.core.history`) is the portable
source of truth: append-only JSON lines, one per benchmark instance per
run.  At fleet scale — many machines × instances × runs — every
consumer re-scanning that file linearly stops holding up, and a single
machine's file cannot absorb other machines' runs at all.  This package
adds the indexed layer a fleet-scale benchmark collection needs,
without demoting the JSONL:

  * :mod:`repro.store.index` — an SQLite mirror (``history.db`` next to
    the JSONL; runs / records / counters tables keyed by scope, family,
    canonical params JSON, sysinfo digest, tag and timestamp).  Built
    *incrementally* from the JSONL by a byte-offset watermark, so
    re-indexing after a run appends is O(new bytes); the whole file is
    rebuildable from scratch at any time (``repro store index
    --rebuild``) and deleting it loses nothing.
  * :mod:`repro.store.query` — filter/aggregate queries over the store
    (``python -m repro query``) whose record output is byte-equivalent
    to a direct JSONL scan: the index stores each record's original
    line, and every SQL pre-filter is re-verified by the same Python
    predicate the scan path uses.
  * :mod:`repro.store.ingest` — ``python -m repro store ingest
    <shard.jsonl>...`` merges history shards from other machines into
    one fleet store, deduplicating whole runs by (run-id, sysinfo
    digest).

The live dashboard over this store is
:mod:`repro.scopeplot.dashboard` (``python -m repro report --serve``).
Operator guide: docs/result-store.md.
"""
from .index import (DB_FILE, db_path, is_fresh, load_records, rebuild,
                    refresh, store_status)
from .ingest import IngestStats, ingest_shards
from .query import (QueryFilter, StreamStats, aggregate_records,
                    match_record, parse_percentiles, run_query,
                    scan_records, split_name)

__all__ = [
    "DB_FILE", "IngestStats", "QueryFilter", "StreamStats",
    "aggregate_records", "db_path", "ingest_shards", "is_fresh",
    "load_records", "match_record", "parse_percentiles", "rebuild",
    "refresh", "run_query", "scan_records", "split_name", "store_status",
]
