"""Filter + aggregate queries over the result store (``repro query``).

Two execution paths, one contract:

  * **store** — SQL pre-filter on the indexed columns (scope, family,
    run, digest, tag, timestamp), then the *same* Python predicate the
    scan path uses re-verifies every candidate row's parsed record;
  * **scan** — a direct pass over ``history.jsonl``
    (:func:`repro.core.history.scan_history` semantics).

Because the index stores every record's original line and the final
predicate is shared, the two paths return byte-identical output for
identical filters — ``--no-store`` (or a missing/stale index) changes
the cost of a query, never its answer.

Aggregation is **streaming**: per-name means/stddevs via Welford and
percentiles via the P² estimator (:class:`repro.core.quantile.
StreamingQuantile`), so a fleet-scale percentile query over counters
holds five markers per quantile instead of materializing per-record
sample lists.  Below five samples P² is exact — tests pin it against
:func:`repro.core.quantile.percentile`.
"""
from __future__ import annotations

import json
import math
import os
import sqlite3
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.benchmark import match_params, name_params
from repro.core.logging import get_logger
from repro.core.quantile import StreamingQuantile

from . import index as store_index

log = get_logger("store")

Record = Dict[str, Any]
#: (original line text, parsed record) — what both query paths yield.
Row = Tuple[str, Record]

DEFAULT_PERCENTILES = ("p50", "p90", "p99")


def split_name(name: str) -> Tuple[str, str]:
    """``(scope, family)`` of an instance name.

    The family is the leading components before the first typed
    ``axis:value`` (or legacy integer) argument: ``mxu/matmul/dtype:bf16
    /n:512`` → ``("mxu", "mxu/matmul")``; ``example/saxpy/1024`` →
    ``("example", "example/saxpy")``.
    """
    parts = name.split("/")
    fam: List[str] = []
    for part in parts:
        if ":" in part:
            break
        if fam and (part.isdigit()
                    or (part.startswith("-") and part[1:].isdigit())):
            break
        fam.append(part)
    return parts[0], "/".join(fam) if fam else name


def parse_percentiles(spec: str) -> List[Tuple[str, float]]:
    """``"p50,p99,p999"`` → ``[("p50", 0.50), ...]``; validates range."""
    out: List[Tuple[str, float]] = []
    for part in spec.split(","):
        label = part.strip().lower()
        if not label:
            continue
        digits = label[1:] if label.startswith("p") else ""
        if not digits.isdigit():
            raise ValueError(f"bad percentile {part!r} "
                             f"(expected p50/p90/p99/p999 style)")
        q = int(digits) / (10 ** len(digits))
        if not 0.0 < q < 1.0:
            raise ValueError(f"percentile {part!r} out of (0, 1)")
        if label not in [lb for lb, _ in out]:
            out.append((label, q))
    if not out:
        raise ValueError("--percentiles needs at least one pN value")
    return out


@dataclass
class QueryFilter:
    """What ``repro query`` selects.  All fields AND together; ``params``
    follows ``--param`` semantics (values for one key OR together)."""

    scope: Optional[str] = None
    family: Optional[str] = None
    name: Optional[str] = None            # exact instance name
    params: Optional[Dict[str, List[str]]] = None
    sysinfo: Optional[str] = None         # sysinfo digest
    tag: Optional[str] = None             # "" selects untagged records
    run_id: Optional[str] = None
    since: Optional[str] = None           # ISO prefix, inclusive
    until: Optional[str] = None           # ISO prefix, inclusive
    fingerprint: Optional[str] = None     # instance fingerprint digest

    def describe(self) -> str:
        parts = []
        for key in ("scope", "family", "name", "sysinfo", "tag",
                    "run_id", "since", "until", "fingerprint"):
            v = getattr(self, key)
            if v is not None:
                parts.append(f"{key}={v}")
        if self.params:
            parts += [f"param {k}={'|'.join(v)}"
                      for k, v in self.params.items()]
        return ", ".join(parts) or "everything"


def match_record(rec: Record, flt: QueryFilter) -> bool:
    """The single predicate both query paths apply to a parsed record."""
    name = rec.get("name", "")
    scope, family = split_name(name)
    if flt.scope is not None and scope != flt.scope:
        return False
    if flt.family is not None and family != flt.family:
        return False
    if flt.name is not None and name != flt.name:
        return False
    if flt.sysinfo is not None and rec.get("sysinfo", "") != flt.sysinfo:
        return False
    if flt.tag is not None and (rec.get("tag") or "") != flt.tag:
        return False
    if flt.run_id is not None and rec.get("run_id", "") != flt.run_id:
        return False
    if flt.fingerprint is not None \
            and (rec.get("fingerprint") or "") != flt.fingerprint:
        return False
    ts = rec.get("ts", "") or ""
    if flt.since is not None and ts < flt.since:
        return False
    if flt.until is not None and ts > flt.until \
            and not ts.startswith(flt.until):
        return False
    if flt.params and not match_params(name_params(name), flt.params):
        return False
    return True


def scan_records(history_file: str, flt: QueryFilter) -> Iterator[Row]:
    """Direct JSONL scan — the reference the store path must equal."""
    from repro.core.history import iter_lines
    for raw, rec in iter_lines(history_file):
        if match_record(rec, flt):
            yield raw, rec


def _store_rows(history_file: str, flt: QueryFilter) -> Iterator[Row]:
    """SQL pre-filter on indexed columns, re-verified in Python.

    Raises :class:`repro.store.index.StoreStale` when the index can't
    mirror the file right now — callers fall back to the scan.
    """
    stats = store_index.refresh(history_file)
    if not stats.usable:
        raise store_index.StoreStale(history_file)
    where, args = ["1=1"], []
    for col, val in (("scope", flt.scope), ("family", flt.family),
                     ("name", flt.name), ("sysinfo", flt.sysinfo),
                     ("tag", flt.tag), ("run_id", flt.run_id),
                     ("fingerprint", flt.fingerprint)):
        if val is not None:
            where.append(f"{col} = ?")
            args.append(val)
    if flt.since is not None:
        where.append("ts >= ?")
        args.append(flt.since)
    if flt.until is not None:
        # inclusive ISO-prefix: "2026-07-31" keeps "2026-07-31T23:59"
        where.append("(ts <= ? OR ts LIKE ?)")
        args += [flt.until, flt.until + "%"]
    con = sqlite3.connect(stats.db_file)
    try:
        rows = con.execute(
            f"SELECT raw FROM records WHERE {' AND '.join(where)} "
            f"ORDER BY id", args)
        for (raw,) in rows:
            rec = json.loads(raw)
            if match_record(rec, flt):    # shared final predicate
                yield raw, rec
    finally:
        con.close()


def run_query(history_file: str, flt: QueryFilter,
              use_store: str = "auto") -> Iterator[Row]:
    """Yield matching ``(raw line, record)`` pairs in append order.

    ``use_store``: ``"auto"`` takes the index when present (building it
    is ``repro store index``'s job, not a query side effect) and falls
    back to the scan on any index problem; ``"never"`` forces the scan;
    ``"always"`` builds/refreshes the index first.
    """
    history_file = os.path.abspath(history_file)
    if use_store != "never":
        has_db = os.path.exists(store_index.db_path(history_file))
        if use_store == "always" or has_db:
            try:
                yield from _store_rows(history_file, flt)
                return
            except store_index.StoreStale as e:
                log.warning("store index unusable (%s); scanning %s "
                            "directly", e, history_file)
            except sqlite3.Error as e:
                log.warning("store index broken (%r); scanning %s "
                            "directly", e, history_file)
    yield from scan_records(history_file, flt)


# ---------------------------------------------------------------------------
# streaming aggregation
# ---------------------------------------------------------------------------

class StreamStats:
    """O(1)-memory statistics: Welford mean/stddev + P² percentiles.

    This is the store's counter-aggregation primitive: a fleet-scale
    percentile query feeds every value through five P² markers per
    quantile instead of materializing a sample list.  Exact below five
    samples (pinned against ``repro.core.quantile.percentile``).
    """

    def __init__(self, quantiles: Sequence[Tuple[str, float]] = ()):
        self.n = 0
        self._mean = 0.0
        self._m2 = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._sq = {label: StreamingQuantile(q) for label, q in quantiles}

    def add(self, value: float) -> None:
        v = float(value)
        self.n += 1
        d = v - self._mean
        self._mean += d / self.n
        self._m2 += d * (v - self._mean)
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        for sq in self._sq.values():
            sq.observe(v)

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def stddev(self) -> float:
        if self.n < 2:
            return 0.0
        return math.sqrt(self._m2 / (self.n - 1))

    def result(self) -> Dict[str, float]:
        out = {"n": self.n, "mean": self._mean, "stddev": self.stddev,
               "min": self._min, "max": self._max}
        for label, sq in self._sq.items():
            out[label] = sq.value()
        return out


@dataclass
class Aggregate:
    """Per-instance-name aggregate over a query's record stream."""

    name: str
    records: int = 0
    runs: int = 0
    errors: int = 0
    mean_s: Optional[StreamStats] = None
    counters: Dict[str, StreamStats] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"name": self.name, "records": self.records,
                               "runs": self.runs, "errors": self.errors}
        if self.mean_s is not None and self.mean_s.n:
            out["mean_s"] = self.mean_s.result()
        if self.counters:
            out["counters"] = {k: v.result()
                               for k, v in sorted(self.counters.items())}
        return out


def aggregate_records(rows: Iterable[Row],
                      quantiles: Sequence[Tuple[str, float]] = ()
                      ) -> List[Aggregate]:
    """Fold a record stream into per-name aggregates, single pass.

    ``mean_s`` pools each record's per-run mean; every numeric counter
    is pooled under its own key.  Nothing is buffered per record — the
    stream can be a full fleet store.
    """
    by_name: Dict[str, Aggregate] = {}
    run_seen: Dict[str, set] = {}
    for _raw, rec in rows:
        name = rec.get("name", "")
        agg = by_name.get(name)
        if agg is None:
            agg = by_name[name] = Aggregate(
                name=name, mean_s=StreamStats(quantiles))
            run_seen[name] = set()
        agg.records += 1
        agg.errors += int(rec.get("errors") or 0)
        rid = (rec.get("run_id", ""), rec.get("sysinfo", ""))
        if rid not in run_seen[name]:
            run_seen[name].add(rid)
            agg.runs += 1
        mean = rec.get("mean_s")
        if isinstance(mean, (int, float)) and not isinstance(mean, bool):
            agg.mean_s.add(mean)
        counters = rec.get("counters")
        if isinstance(counters, dict):
            for key, value in counters.items():
                if isinstance(value, (int, float)) \
                        and not isinstance(value, bool):
                    st = agg.counters.get(key)
                    if st is None:
                        st = agg.counters[key] = StreamStats(quantiles)
                    st.add(value)
    return [by_name[n] for n in by_name]     # first-seen order
