"""Fleet ingest — merge history shards from many machines into one store.

``python -m repro store ingest lab-a.jsonl lab-b.jsonl ...`` appends
other machines' history records to this results directory's
``history.jsonl`` and refreshes the index.  The JSONL stays the source
of truth: shard lines are appended **verbatim** (the shards' bytes are
the fleet's measurement record, not something to re-serialize), and
dedup works at *run* granularity — a run is identified by its
``(run_id, sysinfo digest)`` pair, so

  * re-ingesting the same shard is a no-op,
  * a run present in two overlapping shards lands once,
  * two machines that happened to mint the same timestamp run-id keep
    both runs (their sysinfo digests differ — they are different
    measurements, not duplicates).

Partial runs are all-or-nothing per shard: either every record of a
``(run_id, sysinfo)`` group is appended or none is, so a half-ingested
shard can't interleave torn runs into the store.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.history import HISTORY_FILE, iter_lines
from repro.core.logging import get_logger

from . import index as store_index

log = get_logger("store")

RunKey = Tuple[str, str]     # (run_id, sysinfo digest)


@dataclass
class IngestStats:
    """Outcome of one :func:`ingest_shards` pass."""

    history_file: str
    shards: int = 0
    appended: int = 0                       # records written
    new_runs: List[RunKey] = field(default_factory=list)
    duplicate_runs: List[RunKey] = field(default_factory=list)
    skipped_lines: int = 0                  # garbage lines in shards

    def summary(self) -> str:
        return (f"ingested {self.shards} shard(s): {self.appended} "
                f"record(s) across {len(self.new_runs)} new run(s), "
                f"{len(self.duplicate_runs)} duplicate run(s) skipped, "
                f"{self.skipped_lines} garbage line(s) dropped")


def _run_key(rec: Dict) -> RunKey:
    return rec.get("run_id", "") or "", rec.get("sysinfo", "") or ""


def ingest_shards(results_dir: str, shard_paths: List[str],
                  history_file: Optional[str] = None,
                  reindex: bool = True) -> IngestStats:
    """Merge shard JSONL files into ``<results-dir>/history.jsonl``.

    Shards are processed in argument order; within a shard, line order
    is preserved (append order is chronology in a history file).  The
    index is refreshed afterwards (created if this store never had
    one) unless ``reindex=False``.
    """
    if history_file is None:
        history_file = os.path.join(results_dir, HISTORY_FILE)
    history_file = os.path.abspath(history_file)
    stats = IngestStats(history_file=history_file)

    existing: Set[RunKey] = set()
    if os.path.exists(history_file):
        for _line, rec in iter_lines(history_file):
            existing.add(_run_key(rec))

    to_append: List[str] = []
    for shard in shard_paths:
        shard = os.path.abspath(shard)
        if shard == history_file:
            log.warning("ingest: skipping %s (it is the destination "
                        "history file)", shard)
            continue
        stats.shards += 1
        # group the shard's lines by run so a run is appended whole
        groups: Dict[RunKey, List[str]] = {}
        order: List[RunKey] = []
        seen_lines = 0
        for line, rec in iter_lines(shard):
            seen_lines += 1
            key = _run_key(rec)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(line)
        with open(shard, "rb") as f:
            total_lines = sum(1 for raw in f if raw.strip())
        stats.skipped_lines += total_lines - seen_lines
        for key in order:
            if key in existing:
                if key not in stats.duplicate_runs:
                    stats.duplicate_runs.append(key)
                continue
            existing.add(key)
            stats.new_runs.append(key)
            to_append.extend(groups[key])

    if to_append:
        os.makedirs(os.path.dirname(history_file), exist_ok=True)
        with open(history_file, "a") as f:
            for line in to_append:
                f.write(line + "\n")
        stats.appended = len(to_append)
    if reindex and (to_append
                    or os.path.exists(store_index.db_path(history_file))):
        if os.path.exists(history_file):
            store_index.refresh(history_file)
    log.info("ingest: %s", stats.summary())
    return stats
