"""SQLite mirror of ``history.jsonl`` — incremental, rebuildable, droppable.

The JSONL stays the portable source of truth; the database
(``history.db`` next to it) is a *derived index* over the same records:

  * ``records`` — one row per history line, keyed by scope, family,
    canonical params JSON, sysinfo digest, tag, run-id and timestamp,
    plus the **original line text** (``raw``) so query output can be
    byte-equivalent to a direct JSONL scan;
  * ``runs`` — one row per (run-id, sysinfo digest) pair with its
    record count (the fleet-dedup key :mod:`repro.store.ingest` uses);
  * ``counters`` — one row per numeric counter per record, so counter
    aggregation streams through an index instead of re-parsing JSON;
  * ``meta`` — schema version, source path, and the **byte-offset
    watermark**: how far into the JSONL the index has consumed.

Incremental refresh reads only the bytes past the watermark, so
re-indexing after a run appends costs O(new bytes), not O(file).  The
index is rebuilt from scratch whenever the file shrank or its head
bytes changed (the JSONL was truncated or replaced — the watermark is
meaningless then); a rebuild from the same JSONL is byte-deterministic
(nothing time- or environment-dependent is stored).

Torn tails: a final line without a newline is a writer that died
mid-append.  The watermark stops *before* it — the bytes are re-read
on the next refresh, by which time the writer either completed the
line or never will (and the skip-with-warning path takes it).  A
complete-but-unparseable line is warned about and skipped, exactly as
:func:`repro.core.history.scan_history` does, so the two paths always
agree on the record set.
"""
from __future__ import annotations

import hashlib
import json
import os
import sqlite3
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.logging import get_logger

log = get_logger("store")

DB_FILE = "history.db"
SCHEMA_VERSION = 2      # v2: fingerprint + cached columns (repro ci);
#                         v1 databases rebuild from the JSONL on first touch

#: Bytes of the JSONL head fingerprinted to detect file replacement.
_HEAD_SPAN = 512

_SCHEMA = """
CREATE TABLE IF NOT EXISTS meta (
    key TEXT PRIMARY KEY,
    value TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS records (
    id INTEGER PRIMARY KEY,
    run_id TEXT NOT NULL,
    name TEXT NOT NULL,
    scope TEXT NOT NULL,
    family TEXT NOT NULL,
    params TEXT NOT NULL,
    sysinfo TEXT NOT NULL DEFAULT '',
    tag TEXT NOT NULL DEFAULT '',
    ts TEXT NOT NULL DEFAULT '',
    mean_s REAL,
    stddev_s REAL,
    n INTEGER,
    errors INTEGER,
    verdict TEXT NOT NULL DEFAULT '',
    fingerprint TEXT NOT NULL DEFAULT '',
    cached INTEGER NOT NULL DEFAULT 0,
    raw TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_records_name ON records(name);
CREATE INDEX IF NOT EXISTS idx_records_scope ON records(scope);
CREATE INDEX IF NOT EXISTS idx_records_family ON records(family);
CREATE INDEX IF NOT EXISTS idx_records_run ON records(run_id);
CREATE INDEX IF NOT EXISTS idx_records_sysinfo ON records(sysinfo);
CREATE INDEX IF NOT EXISTS idx_records_ts ON records(ts);
CREATE INDEX IF NOT EXISTS idx_records_fingerprint
    ON records(fingerprint);
CREATE TABLE IF NOT EXISTS runs (
    run_id TEXT NOT NULL,
    sysinfo TEXT NOT NULL,
    tag TEXT NOT NULL DEFAULT '',
    first_ts TEXT NOT NULL DEFAULT '',
    records INTEGER NOT NULL DEFAULT 0,
    PRIMARY KEY (run_id, sysinfo)
);
CREATE TABLE IF NOT EXISTS counters (
    record_id INTEGER NOT NULL REFERENCES records(id),
    key TEXT NOT NULL,
    value REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_counters_record ON counters(record_id);
CREATE INDEX IF NOT EXISTS idx_counters_key ON counters(key);
"""


class StoreStale(RuntimeError):
    """The index cannot currently mirror the JSONL exactly (e.g. the
    file ends in a complete record with no newline, which appending
    writers never produce) — consumers must fall back to a direct scan."""


@dataclass
class RefreshStats:
    """Outcome of one :func:`refresh` pass."""

    db_file: str
    rebuilt: bool = False
    indexed: int = 0          # records added this pass
    skipped: int = 0          # complete-but-unparseable lines skipped
    total: int = 0            # records now in the index
    watermark: int = 0        # byte offset consumed
    size: int = 0             # JSONL size at refresh time
    usable: bool = True       # False: fall back to a direct scan

    @property
    def pending(self) -> int:
        """Unconsumed tail bytes (a torn trailing write, usually)."""
        return self.size - self.watermark


def db_path(history_file: str) -> str:
    """The index lives next to its JSONL: ``<dir>/history.db``."""
    return os.path.join(os.path.dirname(os.path.abspath(history_file)),
                        DB_FILE)


def connect(db_file: str) -> sqlite3.Connection:
    con = sqlite3.connect(db_file)
    con.executescript(_SCHEMA)
    return con


def _meta(con: sqlite3.Connection) -> Dict[str, str]:
    return dict(con.execute("SELECT key, value FROM meta"))


def _set_meta(con: sqlite3.Connection, **kv: Any) -> None:
    con.executemany(
        "INSERT INTO meta(key, value) VALUES(?, ?) "
        "ON CONFLICT(key) DO UPDATE SET value = excluded.value",
        [(k, str(v)) for k, v in kv.items()])


def _head_fingerprint(data_head: bytes) -> str:
    return hashlib.sha1(data_head).hexdigest()


def _needs_rebuild(con: sqlite3.Connection, history_file: str,
                   size: int) -> bool:
    meta = _meta(con)
    if meta.get("schema_version") != str(SCHEMA_VERSION):
        return bool(meta)           # fresh empty db needs no "rebuild"
    try:
        watermark = int(meta.get("watermark", "0"))
        head_len = int(meta.get("head_len", "0"))
    except ValueError:
        return True
    if size < watermark or size < head_len:
        return True                 # file shrank: the offsets are lies
    if head_len:
        with open(history_file, "rb") as f:
            head = f.read(head_len)
        if _head_fingerprint(head) != meta.get("head"):
            return True             # file replaced under the same name
    return False


def record_columns(rec: Dict[str, Any]) -> Dict[str, Any]:
    """The indexed columns of one parsed history record."""
    # lazy: query.py imports this module at its top level
    from repro.core.benchmark import name_params

    from .query import split_name
    name = rec.get("name", "")
    scope, family = split_name(name)
    params = name_params(name)
    return {
        "run_id": rec.get("run_id", ""),
        "name": name,
        "scope": scope,
        "family": family,
        "params": json.dumps(params, sort_keys=True),
        "sysinfo": rec.get("sysinfo", "") or "",
        "tag": rec.get("tag", "") or "",
        "ts": rec.get("ts", "") or "",
        "mean_s": rec.get("mean_s"),
        "stddev_s": rec.get("stddev_s"),
        "n": rec.get("n"),
        "errors": rec.get("errors"),
        "verdict": rec.get("verdict", "") or "",
        "fingerprint": rec.get("fingerprint", "") or "",
        "cached": 1 if rec.get("cached") else 0,
    }


def _insert_record(con: sqlite3.Connection, rec: Dict[str, Any],
                   raw: str) -> None:
    cols = record_columns(rec)
    cur = con.execute(
        "INSERT INTO records(run_id, name, scope, family, params, "
        "sysinfo, tag, ts, mean_s, stddev_s, n, errors, verdict, "
        "fingerprint, cached, raw) "
        "VALUES(:run_id, :name, :scope, :family, :params, :sysinfo, "
        ":tag, :ts, :mean_s, :stddev_s, :n, :errors, :verdict, "
        ":fingerprint, :cached, :raw)",
        dict(cols, raw=raw))
    rid = cur.lastrowid
    counters = rec.get("counters")
    if isinstance(counters, dict):
        rows = [(rid, k, float(v)) for k, v in counters.items()
                if isinstance(v, (int, float))
                and not isinstance(v, bool)]
        if rows:
            con.executemany(
                "INSERT INTO counters(record_id, key, value) "
                "VALUES(?, ?, ?)", rows)
    con.execute(
        "INSERT INTO runs(run_id, sysinfo, tag, first_ts, records) "
        "VALUES(:run_id, :sysinfo, :tag, :ts, 1) "
        "ON CONFLICT(run_id, sysinfo) DO UPDATE SET "
        "records = records + 1", cols)


def _parse_line(raw: bytes, path: str, offset: int
                ) -> Tuple[Optional[Dict[str, Any]], Optional[str]]:
    """(record, decoded line) — (None, None) when the line is garbage
    (same skip conditions as :func:`repro.core.history.scan_history`)."""
    try:
        text = raw.decode("utf-8")
    except UnicodeDecodeError:
        log.warning("%s: skipping undecodable history line at byte %d",
                    path, offset)
        return None, None
    stripped = text.strip()
    if not stripped:
        return None, text
    try:
        rec = json.loads(stripped)
    except json.JSONDecodeError:
        log.warning("%s: skipping unparseable history line at byte %d",
                    path, offset)
        return None, None
    if not isinstance(rec, dict) or "name" not in rec:
        return None, None
    return rec, stripped


def refresh(history_file: str, db_file: Optional[str] = None,
            force_rebuild: bool = False) -> RefreshStats:
    """Bring the index up to date with its JSONL, incrementally.

    Reads only the bytes past the stored watermark; rebuilds from byte
    zero when forced, when the schema changed, or when the file shrank
    or was replaced.  Raises ``OSError`` when the JSONL is missing —
    the index never outlives its source of truth.
    """
    history_file = os.path.abspath(history_file)
    db_file = db_file or db_path(history_file)
    size = os.path.getsize(history_file)

    con = connect(db_file)
    try:
        stats = RefreshStats(db_file=db_file, size=size)
        if force_rebuild or _needs_rebuild(con, history_file, size):
            con.executescript(
                "DELETE FROM counters; DELETE FROM records; "
                "DELETE FROM runs; DELETE FROM meta;")
            stats.rebuilt = True
        meta = _meta(con)
        watermark = int(meta.get("watermark", "0") or 0)

        with open(history_file, "rb") as f:
            f.seek(watermark)
            data = f.read(size - watermark)
        offset = watermark
        usable_tail = True
        for raw in data.splitlines(keepends=True):
            if not raw.endswith(b"\n"):
                # torn trailing write: leave it for the next refresh.
                # If it already parses as a record, the JSONL holds data
                # the index doesn't — consumers must scan directly.
                rec, _ = _parse_line(raw, history_file, offset)
                if rec is not None:
                    usable_tail = False
                break
            rec, _ = _parse_line(raw, history_file, offset)
            if rec is None:
                stats.skipped += 1
            else:
                _insert_record(con, rec, raw.decode("utf-8").strip())
                stats.indexed += 1
            offset += len(raw)

        head_len = min(size, _HEAD_SPAN)
        with open(history_file, "rb") as f:
            head = f.read(head_len)
        _set_meta(con, schema_version=SCHEMA_VERSION,
                  source=history_file, watermark=offset,
                  head_len=head_len, head=_head_fingerprint(head))
        con.commit()
        stats.watermark = offset
        stats.usable = usable_tail
        stats.total = con.execute(
            "SELECT COUNT(*) FROM records").fetchone()[0]
        if stats.indexed or stats.rebuilt:
            log.info("store: %s %s (+%d record(s), %d total, "
                     "watermark %d/%d bytes)",
                     "rebuilt" if stats.rebuilt else "refreshed",
                     db_file, stats.indexed, stats.total, offset, size)
        return stats
    finally:
        con.close()


def rebuild(history_file: str, db_file: Optional[str] = None
            ) -> RefreshStats:
    """Drop everything and re-index the whole JSONL from byte zero."""
    return refresh(history_file, db_file, force_rebuild=True)


def is_fresh(history_file: str, db_file: Optional[str] = None) -> bool:
    """True when the index exists and its watermark covers the JSONL."""
    history_file = os.path.abspath(history_file)
    db_file = db_file or db_path(history_file)
    if not os.path.exists(db_file) or not os.path.exists(history_file):
        return False
    con = sqlite3.connect(db_file)
    try:
        try:
            meta = dict(con.execute("SELECT key, value FROM meta"))
        except sqlite3.Error:
            return False
    finally:
        con.close()
    if meta.get("schema_version") != str(SCHEMA_VERSION):
        return False
    try:
        return int(meta.get("watermark", "-1")) \
            == os.path.getsize(history_file)
    except ValueError:
        return False


def load_records(history_file: str, db_file: Optional[str] = None
                 ) -> List[Dict[str, Any]]:
    """Every history record, in append order, via the index.

    Refreshes the index first (cheap: watermark), so the result always
    equals :func:`repro.core.history.scan_history` over the same file;
    raises :class:`StoreStale` when it provably couldn't (consumers
    fall back to the direct scan).
    """
    stats = refresh(history_file, db_file)
    if not stats.usable:
        raise StoreStale(f"{history_file} has an unindexed parseable "
                         f"tail ({stats.pending} byte(s))")
    con = sqlite3.connect(stats.db_file)
    try:
        rows = con.execute("SELECT raw FROM records ORDER BY id")
        return [json.loads(raw) for (raw,) in rows]
    finally:
        con.close()


def store_status(history_file: str, db_file: Optional[str] = None
                 ) -> Dict[str, Any]:
    """Index freshness + table counts (``repro store status``)."""
    history_file = os.path.abspath(history_file)
    db_file = db_file or db_path(history_file)
    out: Dict[str, Any] = {
        "history": history_file,
        "history_bytes": (os.path.getsize(history_file)
                          if os.path.exists(history_file) else None),
        "db": db_file,
        "exists": os.path.exists(db_file),
        "fresh": is_fresh(history_file, db_file),
    }
    if out["exists"]:
        con = sqlite3.connect(db_file)
        try:
            meta = dict(con.execute("SELECT key, value FROM meta"))
            out["watermark"] = int(meta.get("watermark", "0") or 0)
            out["schema_version"] = meta.get("schema_version")
            for table in ("records", "runs", "counters"):
                out[table] = con.execute(
                    f"SELECT COUNT(*) FROM {table}").fetchone()[0]
            out["machines"] = con.execute(
                "SELECT COUNT(DISTINCT sysinfo) FROM runs").fetchone()[0]
            out["fingerprints"] = con.execute(
                "SELECT COUNT(DISTINCT fingerprint) FROM records "
                "WHERE fingerprint != ''").fetchone()[0]
        except sqlite3.Error:
            out["fresh"] = False
        finally:
            con.close()
    return out
