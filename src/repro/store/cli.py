"""``python -m repro query`` and ``python -m repro store`` entry points.

``query`` filters and aggregates the run history; ``store`` manages the
SQLite index over it (``index``/``ingest``/``status``).  Query output is
independent of whether the index is used: ``--no-store`` (or a missing
index) changes cost, never answers — tests assert byte-equivalence.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List

from repro.core.benchmark import parse_param_filter
from repro.core.cli_examples import epilog
from repro.core.history import HISTORY_FILE
from repro.core.logging import get_logger

from . import index as store_index
from .ingest import ingest_shards
from .query import (DEFAULT_PERCENTILES, QueryFilter, aggregate_records,
                    parse_percentiles, run_query)

log = get_logger("store")


def _history_path(ns: argparse.Namespace) -> str:
    if ns.history:
        return ns.history
    return os.path.join(ns.results_dir, HISTORY_FILE)


def _add_source_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--results-dir", default="results",
                    help="results directory holding history.jsonl and "
                         "its history.db index (default: results)")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="query this history JSONL instead of "
                         "<results-dir>/history.jsonl")


def build_query_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro query",
                                 epilog=epilog("query"),
                                 formatter_class=
                                 argparse.RawDescriptionHelpFormatter)
    _add_source_args(ap)
    ap.add_argument("--scope", default=None,
                    help="only records of this scope")
    ap.add_argument("--family", default=None,
                    help="only records of this benchmark family "
                         "(e.g. mxu/matmul)")
    ap.add_argument("--name", default=None,
                    help="only records with this exact instance name")
    ap.add_argument("--param", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="only instances whose typed parameter KEY "
                         "equals VALUE (repeatable; same KEY twice ORs, "
                         "distinct KEYs AND)")
    ap.add_argument("--sysinfo", default=None, metavar="DIGEST",
                    help="only records from this sysinfo digest "
                         "(one machine/software configuration)")
    ap.add_argument("--tag", default=None,
                    help="only records with this tag ('' for untagged)")
    ap.add_argument("--run-id", default=None,
                    help="only records of this run")
    ap.add_argument("--fingerprint", default=None, metavar="DIGEST",
                    help="only records carrying this instance "
                         "fingerprint (repro.core.fingerprint; "
                         "docs/continuous-benchmarking.md)")
    ap.add_argument("--since", default=None, metavar="ISO",
                    help="only records at/after this ISO timestamp "
                         "prefix (e.g. 2026-08-01)")
    ap.add_argument("--until", default=None, metavar="ISO",
                    help="only records at/before this ISO timestamp "
                         "prefix (inclusive: 2026-08-01 keeps the whole "
                         "day)")
    ap.add_argument("--aggregate", action="store_true",
                    help="fold matches into per-instance statistics "
                         "(mean/stddev/min/max/percentiles over run "
                         "means and every numeric counter) instead of "
                         "listing records")
    ap.add_argument("--percentiles", default=",".join(DEFAULT_PERCENTILES),
                    metavar="LIST",
                    help="percentiles --aggregate reports, P² streaming "
                         "estimates (default: %(default)s; p999 = 0.999)")
    ap.add_argument("--format", default="table",
                    choices=["table", "json", "jsonl"],
                    help="output format (jsonl prints matching history "
                         "lines verbatim; default: table)")
    ap.add_argument("--no-store", action="store_true",
                    help="force a direct JSONL scan, ignoring any index "
                         "(same output, different cost)")
    return ap


def _short(s: str, width: int) -> str:
    return s if len(s) <= width else s[:width - 1] + "…"


def _print_records_table(rows: List[tuple]) -> None:
    recs = [rec for _raw, rec in rows]
    width = max([len(r.get("name", "")) for r in recs] + [8])
    print(f"{'instance':<{width}}  {'mean_s':>12}  {'stddev_s':>10}  "
          f"{'n':>5}  {'err':>3}  {'verdict':<8}  {'run':<19}  tag")
    for r in recs:
        mean = r.get("mean_s")
        std = r.get("stddev_s")
        print(f"{r.get('name', ''):<{width}}  "
              f"{mean if mean is not None else float('nan'):>12.6g}  "
              f"{std if std is not None else float('nan'):>10.4g}  "
              f"{r.get('n') or 0:>5d}  {r.get('errors') or 0:>3d}  "
              f"{_short(r.get('verdict') or '-', 8):<8}  "
              f"{_short(r.get('run_id', ''), 19):<19}  "
              f"{r.get('tag') or ''}")
    print(f"\n{len(recs)} record(s)")


def _print_aggregate_table(aggs, labels: List[str]) -> None:
    width = max([len(a.name) for a in aggs] + [8])
    cols = ["mean", "stddev"] + labels
    header = f"{'instance':<{width}}  {'recs':>5}  {'runs':>5}  {'err':>4}"
    for c in cols:
        header += f"  {c:>11}"
    print(header)
    for a in aggs:
        st = a.mean_s.result() if a.mean_s and a.mean_s.n else {}
        line = (f"{a.name:<{width}}  {a.records:>5d}  {a.runs:>5d}  "
                f"{a.errors:>4d}")
        for c in cols:
            v = st.get(c)
            line += f"  {v:>11.6g}" if v is not None else f"  {'-':>11}"
        print(line)
    print(f"\n{len(aggs)} instance(s)")


def query_main(argv: List[str]) -> int:
    ap = build_query_parser()
    ns = ap.parse_args(argv)

    try:
        params = parse_param_filter(ns.param)
        quantiles = parse_percentiles(ns.percentiles)
    except ValueError as e:
        log.error("%s", e)
        return 2

    history = _history_path(ns)
    if not os.path.exists(history):
        log.error("no history at %s (run something first, or point "
                  "--results-dir/--history at it)", history)
        return 1

    flt = QueryFilter(scope=ns.scope, family=ns.family, name=ns.name,
                      params=params or None, sysinfo=ns.sysinfo,
                      tag=ns.tag, run_id=ns.run_id, since=ns.since,
                      until=ns.until, fingerprint=ns.fingerprint)
    rows = run_query(history, flt,
                     use_store="never" if ns.no_store else "auto")

    if ns.aggregate:
        aggs = aggregate_records(rows, quantiles)
        if ns.format == "table":
            if not aggs:
                print(f"0 instance(s) match {flt.describe()}")
                return 0
            _print_aggregate_table(aggs, [lb for lb, _ in quantiles])
        else:
            doc = {"filter": flt.describe(),
                   "instances": [a.to_json() for a in aggs],
                   "records": sum(a.records for a in aggs)}
            if ns.format == "json":
                print(json.dumps(doc, indent=2, sort_keys=True))
            else:                           # jsonl: one instance per line
                for a in aggs:
                    print(json.dumps(a.to_json(), sort_keys=True))
        return 0

    if ns.format == "jsonl":
        # verbatim history lines — byte-equivalent across both paths
        for raw, _rec in rows:
            print(raw)
        return 0
    collected = list(rows)
    if ns.format == "json":
        print(json.dumps([rec for _raw, rec in collected], indent=2))
        return 0
    if not collected:
        print(f"0 record(s) match {flt.describe()}")
        return 0
    _print_records_table(collected)
    return 0


def build_store_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro store",
                                 epilog=epilog("store"),
                                 formatter_class=
                                 argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", metavar="COMMAND")

    idx = sub.add_parser("index",
                         help="build/refresh the SQLite index over "
                              "history.jsonl (incremental: only bytes "
                              "past the watermark are read)")
    _add_source_args(idx)
    idx.add_argument("--rebuild", action="store_true",
                     help="drop the index and re-read the whole JSONL "
                          "(the result is byte-deterministic)")

    ing = sub.add_parser("ingest",
                         help="merge history shards from other machines "
                              "into this store, deduplicating whole "
                              "runs by (run-id, sysinfo digest)")
    _add_source_args(ing)
    ing.add_argument("shards", nargs="+", metavar="SHARD.jsonl",
                     help="history JSONL files to merge in")

    st = sub.add_parser("status",
                        help="index freshness, watermark and table "
                             "counts")
    _add_source_args(st)
    st.add_argument("--format", default="table",
                    choices=["table", "json"])
    st.add_argument("--coverage", action="store_true",
                    help="also load the benchmark scopes and report "
                         "per-scope fingerprint coverage: instances "
                         "whose latest record is fresh (current "
                         "fingerprint), stale (code/params/tuned/stack "
                         "changed since) or never-run on this machine")
    return ap


def store_main(argv: List[str]) -> int:
    ap = build_store_parser()
    ns = ap.parse_args(argv)
    if not ns.command:
        ap.print_help()
        return 2
    history = _history_path(ns)

    if ns.command == "index":
        if not os.path.exists(history):
            log.error("no history at %s; nothing to index", history)
            return 1
        stats = (store_index.rebuild(history) if ns.rebuild
                 else store_index.refresh(history))
        print(f"{'rebuilt' if stats.rebuilt else 'refreshed'} "
              f"{stats.db_file}: +{stats.indexed} record(s), "
              f"{stats.total} total, watermark {stats.watermark}/"
              f"{stats.size} bytes"
              + (f", {stats.skipped} garbage line(s) skipped"
                 if stats.skipped else ""))
        if not stats.usable:
            log.warning("unindexed parseable tail (%d byte(s)); queries "
                        "will scan the JSONL until the writer finishes",
                        stats.pending)
        return 0

    if ns.command == "ingest":
        missing = [s for s in ns.shards if not os.path.exists(s)]
        if missing:
            log.error("shard(s) not found: %s", ", ".join(missing))
            return 1
        results_dir = (os.path.dirname(os.path.abspath(history))
                       if ns.history else ns.results_dir)
        stats = ingest_shards(results_dir, ns.shards,
                              history_file=history)
        print(stats.summary())
        return 0

    # status
    info = store_index.store_status(history)
    if ns.coverage:
        info["coverage"] = _coverage_info(history)
    if ns.format == "json":
        print(json.dumps(info, indent=2, sort_keys=True))
        return 0
    for key in ("history", "history_bytes", "db", "exists", "fresh",
                "watermark", "schema_version", "records", "runs",
                "counters", "machines", "fingerprints"):
        if key in info:
            print(f"{key:15s} {info[key]}")
    if "coverage" in info:
        print()
        print(format_coverage(info["coverage"]))
    return 0


def _coverage_info(history: str) -> dict:
    """Fingerprint coverage vs the registered benchmark suite.

    Loads the scope modules (the heavy part — JAX), so it only runs
    behind ``--coverage``; any load/fingerprint failure degrades to an
    ``error`` field rather than breaking plain status output.
    """
    from repro.core.fingerprint import coverage, registered_benches
    from repro.core.history import load_history
    try:
        benches = registered_benches()
        records = load_history(history) if os.path.exists(history) else []
        return coverage(benches, records)
    except Exception as e:  # noqa: BLE001 - diagnostics, not a gate
        return {"error": f"{type(e).__name__}: {e}"}


def format_coverage(cov: dict) -> str:
    """Render one coverage dict as the status table section."""
    if "error" in cov:
        return f"coverage unavailable: {cov['error']}"
    lines = [f"coverage (sysinfo {cov.get('sysinfo') or '-'}):",
             f"{'scope':<16}  {'fresh':>6}  {'stale':>6}  {'never':>6}"]
    for scope in sorted(cov.get("scopes", {})):
        row = cov["scopes"][scope]
        lines.append(f"{scope:<16}  {row['fresh']:>6d}  "
                     f"{row['stale']:>6d}  {row['never']:>6d}")
    t = cov.get("totals", {})
    lines.append(f"{'total':<16}  {t.get('fresh', 0):>6d}  "
                 f"{t.get('stale', 0):>6d}  {t.get('never', 0):>6d}")
    return "\n".join(lines)
