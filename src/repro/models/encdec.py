"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment brief: ``input_specs``
supplies precomputed frame embeddings ``frames [B, Se, d]``; the encoder
consumes them directly (adding sinusoidal positions).  The decoder is a
standard causal transformer with learned positions and cross-attention.
Whisper uses LayerNorm + GELU and no rotary embedding — driven by the
config (norm="layernorm", act="gelu", use_rope=False).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig

Params = Dict[str, Any]


def _norm(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return L.init_layernorm, L.layer_norm
    return L.init_rmsnorm, L.rms_norm


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2,
                                              dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def init(cfg: ModelConfig, key) -> Params:
    init_n, _ = _norm(cfg)
    n_total = cfg.num_enc_layers + cfg.num_layers
    keys = jax.random.split(key, 2 * n_total + 4)
    d = cfg.d_model

    def enc_block(i):
        return {
            "ln1": init_n(d), "ln2": init_n(d),
            "attn": L.init_attention(keys[2 * i], d, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.hd),
            "mlp": L.init_mlp(keys[2 * i + 1], d, cfg.d_ff, cfg.act),
        }

    def dec_block(i):
        j = cfg.num_enc_layers + i
        k1, k2 = keys[2 * j], keys[2 * j + 1]
        ks = jax.random.split(k1, 2)
        return {
            "ln1": init_n(d), "ln_x": init_n(d), "ln2": init_n(d),
            "attn": L.init_attention(ks[0], d, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.hd),
            "cross": L.init_attention(ks[1], d, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.hd),
            "mlp": L.init_mlp(k2, d, cfg.d_ff, cfg.act),
        }

    stack = lambda blocks: jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": L.init_embed(keys[-1], cfg.vocab_size, d),
        "pos_embed": L.embed_init(keys[-2], (cfg.max_seq, d)),
        "enc_blocks": stack([enc_block(i)
                             for i in range(cfg.num_enc_layers)]),
        "dec_blocks": stack([dec_block(i) for i in range(cfg.num_layers)]),
        "enc_norm": init_n(d),
        "final_norm": init_n(d),
    }


def unembed_table(params: Params) -> jax.Array:
    return params["embed"]["table"]      # whisper ties embeddings


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames [B, Se, d] (precomputed frontend stub) → encoder states."""
    _, norm_f = _norm(cfg)
    B, Se, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + \
        sinusoids(Se, d).astype(jnp.dtype(cfg.dtype))[None]
    ck = L.pick_chunk(Se, cfg.attn_chunk_k)

    def block(x, p):
        h = norm_f(p["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                         cfg.hd, False, cfg.norm_eps)
        o = L.flash_attention_xla(q, k, v, causal=False,
                                  chunk_q=ck, chunk_k=ck)
        x = x + o.reshape(B, Se, -1) @ p["attn"]["wo"].astype(x.dtype)
        h = norm_f(p["ln2"], x, cfg.norm_eps)
        return x + L.mlp(p["mlp"], h, cfg.act), None

    if cfg.remat == "full":
        block = jax.checkpoint(block)
    x, _ = lax.scan(block, x, params["enc_blocks"])
    return norm_f(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _cross_kv(cfg: ModelConfig, p_cross: Params, enc: jax.Array):
    B, Se, _ = enc.shape
    k = (enc @ p_cross["wk"].astype(enc.dtype)).reshape(
        B, Se, cfg.num_kv_heads, cfg.hd)
    v = (enc @ p_cross["wv"].astype(enc.dtype)).reshape(
        B, Se, cfg.num_kv_heads, cfg.hd)
    return k, v


def _decoder(cfg: ModelConfig, params: Params, tokens: jax.Array,
             enc: jax.Array, collect_kv: bool = False):
    """Teacher-forced decoder pass.  Returns (h, kv|None)."""
    _, norm_f = _norm(cfg)
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    x = x + params["pos_embed"][:S].astype(x.dtype)[None]
    Se = enc.shape[1]
    ckx = L.pick_chunk(Se, cfg.attn_chunk_k)

    def block(x, p):
        h = norm_f(p["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                         cfg.hd, False, cfg.norm_eps)
        o = L.flash_attention_xla(q, k, v, causal=True,
                                  chunk_q=cfg.attn_chunk_q,
                                  chunk_k=cfg.attn_chunk_k,
                                  causal_skip=cfg.causal_skip)
        x = x + o.reshape(B, S, -1) @ p["attn"]["wo"].astype(x.dtype)
        # cross-attention
        h = norm_f(p["ln_x"], x, cfg.norm_eps)
        qx = (h @ p["cross"]["wq"].astype(x.dtype)).reshape(
            B, S, cfg.num_heads, cfg.hd)
        kx, vx = _cross_kv(cfg, p["cross"], enc)
        ox = L.flash_attention_xla(qx, kx, vx, causal=False,
                                   chunk_q=cfg.attn_chunk_q, chunk_k=ckx)
        x = x + ox.reshape(B, S, -1) @ p["cross"]["wo"].astype(x.dtype)
        h = norm_f(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h, cfg.act)
        return x, ((k, v, kx, vx) if collect_kv else None)

    if cfg.remat == "full":
        block = jax.checkpoint(block)
    x, kv = lax.scan(block, x, params["dec_blocks"])
    x = norm_f(params["final_norm"], x, cfg.norm_eps)
    return x, kv


def hidden(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
           collect_kv: bool = False):
    enc = encode(cfg, params, batch["frames"])
    h, kv = _decoder(cfg, params, batch["tokens"], enc, collect_kv)
    return h, jnp.zeros((), jnp.float32), kv


def logits(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    return L.unembed(unembed_table(params), h,
                     jnp.dtype(cfg.logits_dtype)), aux


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate([batch["tokens"][:, 1:],
                                  batch["tokens"][:, -1:]], axis=1)
    nll = L.chunked_loss(unembed_table(params), h, labels,
                         cfg.loss_chunk, jnp.dtype(cfg.logits_dtype))
    return nll, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    K, hd, Ln = cfg.num_kv_heads, cfg.hd, cfg.num_layers
    Se = cfg.enc_seq
    return {
        "k": jnp.zeros((Ln, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((Ln, batch, max_len, K, hd), dtype),
        "xk": jnp.zeros((Ln, batch, Se, K, hd), dtype),
        "xv": jnp.zeros((Ln, batch, Se, K, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            cache: Dict[str, Any]):
    h, _aux, kv = hidden(cfg, params, batch, collect_kv=True)
    k, v, xk, xv = kv
    S = batch["tokens"].shape[1]
    cache = dict(cache)
    cache["k"] = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
    cache["xk"] = xk.astype(cache["xk"].dtype)
    cache["xv"] = xv.astype(cache["xv"].dtype)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    out = L.unembed(unembed_table(params), h[:, -1:],
                    jnp.dtype(cfg.logits_dtype))
    return out, cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, Any]):
    _, norm_f = _norm(cfg)
    B = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    x = x + lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1,
                                     axis=0).astype(x.dtype)[None, 0]

    def block(x, inp):
        p, k_c, v_c, xk, xv = inp
        h = norm_f(p["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                         cfg.hd, False, cfg.norm_eps)
        k_c = lax.dynamic_update_slice_in_dim(
            k_c, k.astype(k_c.dtype), pos, axis=1)
        v_c = lax.dynamic_update_slice_in_dim(
            v_c, v.astype(v_c.dtype), pos, axis=1)
        o = L.decode_attention(q, k_c, v_c, pos + 1)
        x = x + o.reshape(B, 1, -1) @ p["attn"]["wo"].astype(x.dtype)
        h = norm_f(p["ln_x"], x, cfg.norm_eps)
        qx = (h @ p["cross"]["wq"].astype(x.dtype)).reshape(
            B, 1, cfg.num_heads, cfg.hd)
        ox = L.naive_attention(qx, xk, xv, causal=False)
        x = x + ox.reshape(B, 1, -1) @ p["cross"]["wo"].astype(x.dtype)
        h = norm_f(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h, cfg.act)
        return x, (k_c, v_c)

    x, (k_new, v_new) = lax.scan(
        block, x, (params["dec_blocks"], cache["k"], cache["v"],
                   cache["xk"], cache["xv"]))
    x = norm_f(params["final_norm"], x, cfg.norm_eps)
    out = L.unembed(unembed_table(params), x, jnp.dtype(cfg.logits_dtype))
    cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
    return out, cache
