"""Mamba2 (SSD) stack — attention-free LM (mamba2-780m).

Sub-quadratic: prefill is chunked-SSD (linear in S), decode is an O(1)
recurrent state update — which is why this family runs the long_500k shape.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig

Params = Dict[str, Any]


def init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 2)
    blocks = []
    for i in range(cfg.num_layers):
        blocks.append({
            "ln": L.init_rmsnorm(cfg.d_model),
            "mamba": L.init_mamba2(keys[i], cfg),
        })
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    p: Params = {
        "embed": L.init_embed(keys[-1], cfg.vocab_size, cfg.d_model),
        "blocks": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"table": L.embed_init(keys[-2],
                                              (cfg.vocab_size, cfg.d_model))}
    return p


def unembed_table(params: Params) -> jax.Array:
    return (params.get("unembed") or params["embed"])["table"]


def hidden(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
           collect_state: bool = False):
    x = L.embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))

    def block(x, p):
        h = L.rms_norm(p["ln"], x, cfg.norm_eps)
        if collect_state:
            y, state, tail = L.mamba2_block(p["mamba"], h, cfg,
                                            return_state=True)
            return x + y, (state, tail)
        y = L.mamba2_block(p["mamba"], h, cfg)
        return x + y, None

    if cfg.remat == "full":
        block = jax.checkpoint(block)
    x, caches = lax.scan(block, x, params["blocks"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), caches


def logits(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    return L.unembed(unembed_table(params), h,
                     jnp.dtype(cfg.logits_dtype)), aux


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate([batch["tokens"][:, 1:],
                                  batch["tokens"][:, -1:]], axis=1)
    nll = L.chunked_loss(unembed_table(params), h, labels,
                         cfg.loss_chunk, jnp.dtype(cfg.logits_dtype))
    return nll, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di, gn = cfg.ssm_d_inner, cfg.ssm_groups * cfg.ssm_state
    km1, Ln = cfg.ssm_conv - 1, cfg.num_layers
    return {
        # recurrent state is carried fp32: it integrates over 500k steps
        "state": jnp.zeros((Ln, batch, H, P, N), jnp.float32),
        "conv": {"x": jnp.zeros((Ln, batch, km1, di), dtype),
                 "B": jnp.zeros((Ln, batch, km1, gn), dtype),
                 "C": jnp.zeros((Ln, batch, km1, gn), dtype)},
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            cache: Dict[str, Any]):
    h, _aux, caches = hidden(cfg, params, batch, collect_state=True)
    states, tails = caches                       # [L,B,H,P,N], {x,B,C}
    S = batch["tokens"].shape[1]
    cache = {
        "state": states.astype(cache["state"].dtype),
        "conv": jax.tree_util.tree_map(
            lambda t, c: t.astype(c.dtype), tails, cache["conv"]),
        "pos": jnp.asarray(S, jnp.int32),
    }
    out = L.unembed(unembed_table(params), h[:, -1:],
                    jnp.dtype(cfg.logits_dtype))
    return out, cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, Any]):
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

    def block(x, inp):
        p, state, tail = inp
        h = L.rms_norm(p["ln"], x, cfg.norm_eps)
        y, state_new, tail_new = L.mamba2_decode_step(
            p["mamba"], h, cfg, ssm_state=state, conv_tail=tail)
        tail_new = jax.tree_util.tree_map(
            lambda a, b: a.astype(b.dtype), tail_new, tail)
        return x + y, (state_new.astype(state.dtype), tail_new)

    x, (state_new, conv_new) = lax.scan(
        block, x, (params["blocks"], cache["state"], cache["conv"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    out = L.unembed(unembed_table(params), x, jnp.dtype(cfg.logits_dtype))
    return out, {"state": state_new, "conv": conv_new,
                 "pos": cache["pos"] + 1}
