"""Neural building blocks — pure-JAX, functional, scan-friendly.

Everything operates on parameter *dicts* (pytrees) produced by the matching
``init_*`` functions so layers can be stacked along a leading axis and driven
by ``jax.lax.scan`` (compact HLO — essential for the 512-device dry-run).

Conventions:
  * activations ``[B, S, ...]``; weights stored fp32 at init, cast to the
    compute dtype by callers (mixed-precision policy lives in repro.train);
  * attention heads layout ``[B, S, H, D]``;
  * GQA with ``K`` kv heads: ``H % K == 0``; K may be smaller than the TP
    axis, in which case kv projections are replicated (see
    repro.distributed.partition).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import shard_map as shard_map_compat
from repro.distributed.logical import constrain

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * scale


def embed_init(key, shape) -> jax.Array:
    return jax.random.normal(key, shape, jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


def pick_chunk(S: int, target: int = 512) -> int:
    """Largest divisor of S that is ≤ target (flash chunking for odd S)."""
    best = 1
    for c in range(1, min(S, target) + 1):
        if S % c == 0:
            best = c
    return best


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"] + p["bias"]
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: Tuple[int, ...] = (),
               enabled: bool = True) -> jax.Array:
    if not enabled:
        return x
    return _apply_rope(x, positions, theta, mrope_sections)


def _apply_rope(x: jax.Array, positions: jax.Array, theta: float,
                mrope_sections: Tuple[int, ...] = ()) -> jax.Array:
    """Rotate ``x [B,S,H,D]`` by position.

    ``positions``: ``[B,S]`` for standard RoPE, or ``[3,B,S]`` for M-RoPE
    (qwen2-vl): the D/2 frequency channels are split into
    ``mrope_sections`` groups (t, h, w), each rotated by its own position
    stream.  Text tokens carry identical t/h/w positions, which makes
    M-RoPE collapse to standard RoPE — a property tested in
    tests/test_models.py.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [D/2]
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs [3,B,S] positions"
        assert sum(mrope_sections) == hd // 2, (mrope_sections, hd)
        # select, per frequency channel, which position stream drives it
        sec_id = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections), total_repeat_length=hd // 2)
        pos = positions.astype(jnp.float32)             # [3,B,S]
        # angle[b,s,c] = pos[sec_id[c],b,s] * freqs[c]
        pos_per_chan = jnp.take(pos, sec_id, axis=0)    # [C,B,S]
        angle = jnp.einsum("cbs,c->bsc", pos_per_chan, freqs)
    else:
        pos = positions.astype(jnp.float32)             # [B,S]
        angle = pos[..., None] * freqs                  # [B,S,D/2]
    cos = jnp.cos(angle)[:, :, None, :]                 # [B,S,1,D/2]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, d: int, H: int, K: int, hd: int,
                   qk_norm: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, H * hd)),
        "wk": dense_init(ks[1], (d, K * hd)),
        "wv": dense_init(ks[2], (d, K * hd)),
        "wo": dense_init(ks[3], (H * hd, d)),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _qkv(p: Params, x: jax.Array, H: int, K: int, hd: int,
         qk_norm: bool, eps: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = constrain((x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd),
                  "batch", None, "heads", None)
    k = constrain((x @ p["wk"].astype(x.dtype)).reshape(B, S, K, hd),
                  "batch", None, "kv_heads", None)
    v = constrain((x @ p["wv"].astype(x.dtype)).reshape(B, S, K, hd),
                  "batch", None, "kv_heads", None)
    if qk_norm:
        q = rms_norm(p["q_norm"], q, eps)
        k = rms_norm(p["k_norm"], k, eps)
    return q, k, v


def repeat_kv(k: jax.Array, H: int) -> jax.Array:
    """GQA: repeat kv heads to H ([B,S,K,D] → [B,S,H,D]).

    The Megatron treatment when TP > kv_heads: kv projections are
    replicated and each device takes the repeats its q-heads need — keeps
    every attention einsum sharded cleanly on one head dim.
    """
    K = k.shape[2]
    if K == H:
        return k
    return jnp.repeat(k, H // K, axis=2)


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    q_offset: int | jax.Array = 0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention, GQA-aware.  q [B,Sq,H,D], k/v [B,Sk,K,D].

    ``q_offset``: absolute position of q[0] (for decode: cache length).
    ``kv_len``: valid prefix length of k/v (rest is padding to ignore).
    """
    B, Sq, H, D = q.shape
    kr = repeat_kv(k, H).astype(jnp.float32)
    vr = repeat_kv(v, H).astype(jnp.float32)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kr) / math.sqrt(D)
    s = constrain(s, "batch", "heads", None, None)
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if kv_len is not None:
        mask &= k_pos < kv_len
    s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)   # fully-masked rows
    o = jnp.einsum("bhqs,bshd->bqhd", w, vr)
    return o.astype(q.dtype)


def _chunk_pairs(Sq: int, Sk: int, cq: int, ck: int, causal: bool,
                 causal_skip: bool):
    """Static (python-int) chunk-pair schedule."""
    nq, nk = Sq // cq, Sk // ck
    if causal and causal_skip:
        # schedule only lower-triangular chunk pairs: ~2x fewer FLOPs than
        # masking a full quadratic sweep (beyond-paper lever, §Perf)
        off = (Sk - Sq) // ck
        return [(i, j) for i in range(nq) for j in range(0, i + off + 1)]
    return [(i, j) for i in range(nq) for j in range(nk)]


def _split_pairs(Sq, Sk, cq, ck, causal, causal_skip):
    """(off-diagonal pairs, diagonal pairs) for the two-scan schedule."""
    pairs = _chunk_pairs(Sq, Sk, cq, ck, causal, causal_skip)
    diag, offd = [], []
    for i, j in pairs:
        # masking needed iff the k-chunk straddles the diagonal: some k
        # position exceeds the chunk's smallest absolute q position
        last_k = j * ck + ck - 1
        first_q_abs = i * cq + (Sk - Sq)
        if causal and last_k > first_q_abs:
            diag.append((i, j))
        else:
            offd.append((i, j))
    return offd, diag


def _flash_fwd_scan(q, kr, vr, causal, cq, ck, causal_skip):
    """Online-softmax over chunk pairs.  q [B,Sq,H,D]; kr/vr [B,Sk,H,D].

    Flash-v2-style schedule (beyond-paper lever, see EXPERIMENTS.md §Perf):
      * causal pairs split into OFF-DIAGONAL (no mask, no -inf selects —
        ~(nq-1)/nq of all pairs) and DIAGONAL scans (masked);
      * dots consume the INPUT dtype with fp32 accumulation
        (``preferred_element_type``) — bf16 activations hit the MXU
        natively with no fp32 operand copies; fp32 inputs stay exact.

    Returns (out fp32 [B,Sq,H,D], lse [B,H,Sq]).
    """
    B, Sq, H, D = q.shape
    Sk = kr.shape[1]
    # fold the softmax scale into q ONCE ([B,Sq,H,D], tiny) instead of a
    # full pass over every [cq,ck] scores tile (−1 scores pass; §Perf A2)
    qs = q * jnp.asarray(1.0 / math.sqrt(D), q.dtype)

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    # acc kept in dot-native [B,H,Sq,D] layout: no per-pair transposes
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)

    def body(carry, ij, masked):
        m, l, acc = carry
        i, j = ij
        qc = lax.dynamic_slice_in_dim(qs, i * cq, cq, axis=1)
        kc = lax.dynamic_slice_in_dim(kr, j * ck, ck, axis=1)
        vc = lax.dynamic_slice_in_dim(vr, j * ck, ck, axis=1)
        s = jnp.einsum("bqhd,bshd->bhqs", qc, kc,
                       preferred_element_type=jnp.float32)
        s = constrain(s, "batch", "heads", None, None)
        if masked:
            q_pos = i * cq + jnp.arange(cq)[:, None] + (Sk - Sq)
            k_pos = j * ck + jnp.arange(ck)[None, :]
            s = jnp.where((k_pos <= q_pos)[None, None], s, -jnp.inf)
        mc = lax.dynamic_slice_in_dim(m, i * cq, cq, axis=2)
        lc = lax.dynamic_slice_in_dim(l, i * cq, cq, axis=2)
        ac = lax.dynamic_slice_in_dim(acc, i * cq, cq, axis=2)
        m_new = jnp.maximum(mc, jnp.max(s, axis=-1))
        if masked:
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None]).astype(vc.dtype)
            p = jnp.where(jnp.isneginf(s), 0.0, p)
            corr = jnp.exp(mc - m_new)
            corr = jnp.where(jnp.isneginf(mc), 0.0, corr)
        else:
            # p emitted directly in v's dtype (bf16 in production): the
            # PV dot reads half the bytes and hits the MXU natively
            p = jnp.exp(s - m_new[..., None]).astype(vc.dtype)
            corr = jnp.exp(mc - m_new)
            corr = jnp.where(jnp.isneginf(mc), 0.0, corr)
        l_new = lc * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
        pv = jnp.einsum("bhqs,bshd->bhqd", p, vc,
                        preferred_element_type=jnp.float32)
        ac = ac * corr[..., None] + pv
        m = lax.dynamic_update_slice_in_dim(m, m_new, i * cq, axis=2)
        l = lax.dynamic_update_slice_in_dim(l, l_new, i * cq, axis=2)
        acc = lax.dynamic_update_slice_in_dim(acc, ac, i * cq, axis=2)
        return (m, l, acc), None

    offd, diag = _split_pairs(Sq, Sk, cq, ck, causal, causal_skip)
    carry = (m0, l0, a0)
    if offd:
        xs = (jnp.asarray([p[0] for p in offd], jnp.int32),
              jnp.asarray([p[1] for p in offd], jnp.int32))
        carry, _ = lax.scan(functools.partial(body, masked=False),
                            carry, xs)
    if diag:
        xs = (jnp.asarray([p[0] for p in diag], jnp.int32),
              jnp.asarray([p[1] for p in diag], jnp.int32))
        carry, _ = lax.scan(functools.partial(body, masked=causal),
                            carry, xs)
    m, l, acc = carry
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe[..., None]                       # [B,H,Sq,D]
    out = jnp.transpose(out, (0, 2, 1, 3))              # → [B,Sq,H,D] once
    lse = jnp.where(l > 0.0, m + jnp.log(l_safe), jnp.inf)
    return out, lse


def _flash_fwd(q, k, v, causal, cq, ck, causal_skip):
    H = q.shape[2]
    kr, vr = repeat_kv(k, H), repeat_kv(v, H)
    out, lse = _flash_fwd_scan(q, kr, vr, causal, cq, ck, causal_skip)
    return out.astype(q.dtype), lse


def _flash_bwd_scan(q, k, v, out, lse, dout, causal, cq, ck, causal_skip):
    """Recompute-based flash backward (no saved per-pair history)."""
    B, Sq, H, D = q.shape
    kr, vr = repeat_kv(k, H), repeat_kv(v, H)
    Sk = kr.shape[1]
    K = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    # scale folded into small [.,S,H,D] tensors once, never over scores:
    #   s  = (q·scale)·k ;  ds = p·(do'·v − δ') with do' = do·scale
    qs = q * jnp.asarray(scale, q.dtype)
    dos = dout * jnp.asarray(scale, dout.dtype)
    # delta'_i = rowsum(do'_i * out_i)  [B,H,Sq]
    delta = jnp.einsum("bqhd,bqhd->bhq", dos.astype(jnp.float32),
                       out.astype(jnp.float32))

    dq0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    dk0 = jnp.zeros((B, Sk, H, D), jnp.float32)
    dv0 = jnp.zeros((B, Sk, H, D), jnp.float32)

    def body(carry, ij, masked):
        dq, dk, dv = carry
        i, j = ij
        qc = lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        qsc = lax.dynamic_slice_in_dim(qs, i * cq, cq, axis=1)
        kc = lax.dynamic_slice_in_dim(kr, j * ck, ck, axis=1)
        vc = lax.dynamic_slice_in_dim(vr, j * ck, ck, axis=1)
        doc = lax.dynamic_slice_in_dim(dout, i * cq, cq, axis=1)
        dosc = lax.dynamic_slice_in_dim(dos, i * cq, cq, axis=1)
        lse_c = lax.dynamic_slice_in_dim(lse, i * cq, cq, axis=2)
        del_c = lax.dynamic_slice_in_dim(delta, i * cq, cq, axis=2)
        s = jnp.einsum("bqhd,bshd->bhqs", qsc, kc,
                       preferred_element_type=jnp.float32)
        s = constrain(s, "batch", "heads", None, None)
        if masked:
            q_pos = i * cq + jnp.arange(cq)[:, None] + (Sk - Sq)
            k_pos = j * ck + jnp.arange(ck)[None, :]
            s = jnp.where((k_pos <= q_pos)[None, None], s, -jnp.inf)
        p = jnp.exp(s - lse_c[..., None])          # masked → exp(-inf)=0
        if masked:
            p = jnp.where(jnp.isneginf(s), 0.0, p)
        pd = p.astype(doc.dtype)
        dvc = jnp.einsum("bhqs,bqhd->bshd", pd, doc,
                         preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhd,bshd->bhqs", dosc, vc,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - del_c[..., None])
        dsd = ds.astype(kc.dtype)
        dqc = jnp.einsum("bhqs,bshd->bqhd", dsd, kc,
                         preferred_element_type=jnp.float32)
        dkc = jnp.einsum("bhqs,bqhd->bshd", dsd, qc,
                         preferred_element_type=jnp.float32)
        dq_i = lax.dynamic_slice_in_dim(dq, i * cq, cq, axis=1) + dqc
        dq = lax.dynamic_update_slice_in_dim(dq, dq_i, i * cq, axis=1)
        dk_j = lax.dynamic_slice_in_dim(dk, j * ck, ck, axis=1) + dkc
        dk = lax.dynamic_update_slice_in_dim(dk, dk_j, j * ck, axis=1)
        dv_j = lax.dynamic_slice_in_dim(dv, j * ck, ck, axis=1) + dvc
        dv = lax.dynamic_update_slice_in_dim(dv, dv_j, j * ck, axis=1)
        return (dq, dk, dv), None

    offd, diag = _split_pairs(Sq, Sk, cq, ck, causal, causal_skip)
    carry = (dq0, dk0, dv0)
    if offd:
        xs = (jnp.asarray([p[0] for p in offd], jnp.int32),
              jnp.asarray([p[1] for p in offd], jnp.int32))
        carry, _ = lax.scan(functools.partial(body, masked=False),
                            carry, xs)
    if diag:
        xs = (jnp.asarray([p[0] for p in diag], jnp.int32),
              jnp.asarray([p[1] for p in diag], jnp.int32))
        carry, _ = lax.scan(functools.partial(body, masked=causal),
                            carry, xs)
    (dq, dk, dv) = carry
    if K != H:                                    # fold GQA repeats back
        G = H // K
        dk = dk.reshape(B, Sk, K, G, D).sum(3)
        dv = dv.reshape(B, Sk, K, G, D).sum(3)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, cq, ck, causal_skip):
    out, _ = _flash_fwd(q, k, v, causal, cq, ck, causal_skip)
    return out


def _flash_vjp_fwd(q, k, v, causal, cq, ck, causal_skip):
    out, lse = _flash_fwd(q, k, v, causal, cq, ck, causal_skip)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, cq, ck, causal_skip, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_scan(q, k, v, out, lse, dout, causal, cq, ck,
                           causal_skip)


_flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        chunk_q: int = 512, chunk_k: int = 512,
                        causal_skip: bool = True) -> jax.Array:
    """Chunked online-softmax attention in pure XLA with a custom VJP.

    * never materializes [Sq, Sk];
    * backward recomputes per chunk-pair (flash algorithm), so residuals
      are O(S·H·D) — a lax.scan with autodiff would instead save every
      per-pair carry (observed 16 GiB/device on llama train_4k before this
      custom VJP; see EXPERIMENTS.md §Perf);
    * ``causal_skip`` schedules only lower-triangular chunk pairs.

    The TPU fast path is the Pallas kernel in repro.kernels.flash_attention;
    this XLA formulation is what the 512-device dry-run compiles (Pallas
    does not lower to the CPU backend).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, cq, Sk, ck)
    return _flash_attention(q, k, v, causal, cq, ck, causal_skip)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """Single-token attention against a (padded) KV cache.

    q [B,1,H,D]; caches [B,Smax,K,D]; cache_len: valid prefix (includes the
    token just written).  Softmax over the padded axis is masked.

    Cache-dtype-native: scores/outputs accumulate in fp32 via
    ``preferred_element_type`` but the cache operands are NEVER converted —
    a ``cache.astype(f32)`` here gets hoisted out of the layer scan by
    XLA's loop-widening pass, materializing the whole multi-GiB cache in
    fp32 (observed +12 GiB/device on moonshot decode_32k).

    Numerics mirror ``_flash_fwd_scan`` op-for-op (scale folded into q in
    the cache dtype; probabilities rounded to the value dtype BEFORE the
    normalizing sum; out = pv / l): decode must reproduce the prefill
    path's rounding, otherwise ulp-level drift in the hidden state flips
    near-tied MoE router choices and decode diverges from teacher forcing
    (observed on deepseek-moe-16b: a top-2 gate at 0.506/0.494 flipped at
    layer 0, 0.41 logit error downstream).
    """
    B, _, H, D = q.shape
    # barrier: without it, the CPU backend legalizes the bf16 dot below as
    # convert(f32)+dot, and LICM hoists the convert of the *whole stacked
    # cache* out of the layer scan (+12 GiB/device observed).  On TPU the
    # dot is native bf16 and the barrier is free.
    k_cache, v_cache = lax.optimization_barrier((k_cache, v_cache))
    kr = repeat_kv(k_cache, H)
    vr = repeat_kv(v_cache, H)
    qs = q.astype(kr.dtype) * jnp.asarray(1.0 / math.sqrt(D), kr.dtype)
    s = jnp.einsum("bqhd,bshd->bhqs", qs, kr,
                   preferred_element_type=jnp.float32)
    s = constrain(s, "batch", "heads", None, None)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:                      # ragged: per-row valid prefix [B]
        cl = cl[:, None, None, None]
    mask = jnp.arange(kr.shape[1])[None, None, None, :] < cl
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m_safe[..., None]).astype(vr.dtype)
    p = jnp.where(jnp.isneginf(s), 0.0, p)
    l = jnp.sum(p.astype(jnp.float32), axis=-1)
    pv = jnp.einsum("bhqs,bshd->bhqd", p, vr,
                    preferred_element_type=jnp.float32)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = jnp.transpose(pv / l_safe[..., None], (0, 2, 1, 3))
    return o.astype(q.dtype)


def attention_block(p: Params, x: jax.Array, positions: jax.Array, *,
                    cfg, causal: bool = True) -> jax.Array:
    """Full self-attention sublayer (projections + rope + attention)."""
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q, k, v = _qkv(p, x, H, K, hd, cfg.qk_norm, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections,
                   cfg.use_rope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections,
                   cfg.use_rope)
    if cfg.attn_impl == "naive":
        o = naive_attention(q, k, v, causal=causal)
    else:
        o = flash_attention_xla(q, k, v, causal=causal,
                                chunk_q=cfg.attn_chunk_q,
                                chunk_k=cfg.attn_chunk_k,
                                causal_skip=cfg.causal_skip)
    B, S = x.shape[:2]
    return o.reshape(B, S, H * hd) @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str = "silu") -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, ff)),
         "w_down": dense_init(ks[1], (ff, d))}
    if act == "silu":
        p["w_gate"] = dense_init(ks[2], (d, ff))
    return p


def mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    up = constrain(x @ p["w_up"].astype(x.dtype), "batch", None, "ff")
    if act == "silu":
        gate = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    return constrain(h @ p["w_down"].astype(x.dtype), "batch", None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based scatter dispatch; einsum reference)
# ---------------------------------------------------------------------------

def init_moe(key, d: int, E: int, ff: int, n_shared: int,
             act: str = "silu") -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        "w_up": dense_init(ks[1], (E, d, ff)),
        "w_down": dense_init(ks[2], (E, ff, d)),
    }
    if act == "silu":
        p["w_gate"] = dense_init(ks[3], (E, d, ff))
    if n_shared:
        p["shared"] = init_mlp(ks[4], d, ff * n_shared, act)
    return p


def _router(p: Params, x: jax.Array, top_k: int
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Return (gates [...,k], expert_idx [...,k], aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ p["router"])          # [..., E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * mean_e(frac_tokens_e * mean_prob_e)
    E = probs.shape[-1]
    onehot = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    frac = jnp.mean(onehot.reshape(-1, E), axis=0)
    mprob = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac * mprob)
    return gates, idx, aux


def moe_capacity(tokens_per_group: int, E: int, top_k: int,
                 capacity_factor: float) -> int:
    c = int(math.ceil(tokens_per_group * top_k / E * capacity_factor))
    return max(8, -(-c // 8) * 8)          # ≥8 and multiple of 8 (layout)


def moe_scatter(p: Params, x: jax.Array, *, top_k: int,
                capacity_factor: float, act: str = "silu",
                n_shared: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based MoE with scatter dispatch (the production path).

    x: [B, S, d].  Groups are sequences (S > 1) or the whole batch (decode).
    Tokens beyond an expert's capacity are dropped (standard capacity-based
    routing); capacity_factor controls the drop rate.

    Expert weights [E, d, ff] shard E over the 'model' axis (EP); the
    scatter/gather across the token→expert layout change is where XLA
    inserts the all-to-all.
    """
    B, S, d = x.shape
    E = p["w_up"].shape[0]
    decode = S == 1
    xg = x.reshape(1, B, d) if decode else x                # [G, T, d]
    G, T, _ = xg.shape
    C = moe_capacity(T, E, top_k, capacity_factor)

    gates, idx, aux = _router(p, xg, top_k)                 # [G,T,k]
    flat_e = idx.reshape(G, T * top_k)                      # [G, Tk]
    gate_flat = gates.reshape(G, T * top_k)
    # position of each assignment within its expert (first-come-first-served)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # [G,Tk,E]
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1)
    pos_in_e = jnp.sum(pos_in_e * onehot, axis=-1)          # [G,Tk]
    keep = pos_in_e < C
    pos_c = jnp.where(keep, pos_in_e, C - 1)

    x_rep = jnp.repeat(xg, top_k, axis=1)                   # [G,Tk,d]
    x_rep = jnp.where(keep[..., None], x_rep, 0)
    gidx = jnp.arange(G)[:, None]
    buf = jnp.zeros((G, E, C, d), x.dtype)
    buf = buf.at[gidx, flat_e, pos_c].add(x_rep)            # dispatch
    # the token→expert layout change: E goes to the EP ('model') axis here,
    # which is where XLA inserts the all-to-all
    buf = constrain(buf, None, "experts", None, None)

    # expert FFN: [G,E,C,d] x [E,d,f]
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    if act == "silu":
        gt = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gt) * up
    else:
        h = jax.nn.gelu(up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    out_buf = constrain(out_buf, None, "experts", None, None)

    y_tok = out_buf[gidx, flat_e, pos_c]                    # gather back
    y_tok = y_tok * (gate_flat * keep)[..., None].astype(x.dtype)
    y = jnp.sum(y_tok.reshape(G, T, top_k, d), axis=2)      # combine
    y = y.reshape(B, S, d)
    if n_shared:
        y = y + mlp(p["shared"], x, act)
    return y, aux


def moe_einsum(p: Params, x: jax.Array, *, top_k: int,
               capacity_factor: float, act: str = "silu",
               n_shared: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Reference MoE: dense one-hot dispatch/combine einsums (Mesh-TF style).

    O(T·E·C) memory — only used for small shapes and as the oracle the
    scatter path is tested against.
    """
    B, S, d = x.shape
    E = p["w_up"].shape[0]
    decode = S == 1
    xg = x.reshape(1, B, d) if decode else x
    G, T, _ = xg.shape
    C = moe_capacity(T, E, top_k, capacity_factor)

    gates, idx, aux = _router(p, xg, top_k)
    # dispatch[g,t,e,c] — position via per-expert cumsum over (t,k) order
    flat_e = idx.reshape(G, T * top_k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=1) - 1) * onehot, -1)
    keep = pos < C
    disp = (jax.nn.one_hot(flat_e, E, dtype=xg.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                             dtype=xg.dtype)[..., None, :-1])  # [G,Tk,E,C]
    comb = disp * gates.reshape(G, T * top_k)[..., None, None]
    disp = disp.reshape(G, T, top_k, E, C).sum(2)
    comb = comb.reshape(G, T, top_k, E, C).sum(2)

    buf = jnp.einsum("gtec,gtd->gecd", disp, xg)
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    if act == "silu":
        gt = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gt) * up
    else:
        h = jax.nn.gelu(up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("gtec,gecd->gtd", comb, out_buf).reshape(B, S, d)
    if n_shared:
        y = y + mlp(p["shared"], x, act)
    return y, aux


def moe_shard_map(p: Params, x: jax.Array, cfg, rules
                  ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map — the production dispatch.

    Each model-axis rank owns E/|model| experts.  Activations are already
    replicated across 'model' (they're only batch-sharded), so every rank
    routes all of its tokens, scatters ONLY the assignments that target a
    local expert into a small [G, E_loc, C, d] buffer, runs its experts,
    and the per-rank partial outputs are psum'd — the same all-reduce
    shape TP pays for a dense MLP.

    Why not pjit-level scatter: XLA cannot shard a scatter's target dim,
    so the [G, E, C, d] dispatch buffer materializes E-replicated per
    device (observed 2.5 GiB × live-window on jamba prefill_32k).  Here
    the scatter target is E_loc by construction.
    """
    from jax.sharding import PartitionSpec as P
    mesh = rules["mesh"]
    ep_axis = rules["experts"]
    batch = rules["batch"]
    E = p["w_up"].shape[0]
    n_ranks = mesh.shape[ep_axis] if isinstance(ep_axis, str) else 1
    E_loc = E // n_ranks
    top_k = cfg.moe_top_k

    x_spec = P(batch, None, None) if x.shape[0] % _dpsize(mesh, batch) == 0 \
        else P(None, None, None)
    w_specs = {
        "router": P(None, None),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }
    if "w_gate" in p:
        w_specs["w_gate"] = P(ep_axis, None, None)
    weights = {k: p[k] for k in w_specs}

    def local_fn(x_loc, w):
        B, S, d = x_loc.shape
        decode = S == 1
        xg = x_loc.reshape(1, B, d) if decode else x_loc
        G, T, _ = xg.shape
        C = moe_capacity(T, E, top_k, cfg.moe_capacity_factor)
        gates, idx, aux = _router({"router": w["router"]}, xg, top_k)
        rank = lax.axis_index(ep_axis)
        local = idx - rank * E_loc                       # [G,T,k]
        flat_e = local.reshape(G, T * top_k)
        gate_flat = gates.reshape(G, T * top_k)
        # position within expert counted over the GLOBAL expert id so all
        # ranks agree on capacity-based drops
        onehot = jax.nn.one_hot(idx.reshape(G, T * top_k), E,
                                dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=1) - 1) * onehot, -1)
        mine = (flat_e >= 0) & (flat_e < E_loc)
        keep = (pos < C) & mine
        e_c = jnp.where(keep, flat_e, 0)
        pos_c = jnp.where(keep, pos, C - 1)
        x_rep = jnp.repeat(xg, top_k, axis=1)
        x_rep = jnp.where(keep[..., None], x_rep, 0)
        gidx = jnp.arange(G)[:, None]
        buf = jnp.zeros((G, E_loc, C, d), x_loc.dtype)
        buf = buf.at[gidx, e_c, pos_c].add(x_rep)
        up = jnp.einsum("gecd,edf->gecf", buf, w["w_up"].astype(x_loc.dtype))
        if cfg.act == "silu":
            gt = jnp.einsum("gecd,edf->gecf", buf,
                            w["w_gate"].astype(x_loc.dtype))
            hh = jax.nn.silu(gt) * up
        else:
            hh = jax.nn.gelu(up)
        out_buf = jnp.einsum("gecf,efd->gecd", hh,
                             w["w_down"].astype(x_loc.dtype))
        y_tok = out_buf[gidx, e_c, pos_c]
        y_tok = y_tok * (gate_flat * keep)[..., None].astype(x_loc.dtype)
        y = jnp.sum(y_tok.reshape(G, T, top_k, d), axis=2)
        y = lax.psum(y, ep_axis)            # combine across expert ranks
        return y.reshape(B, S, d), aux

    y, aux = shard_map_compat(
        local_fn, mesh=mesh,
        in_specs=(x_spec, w_specs),
        out_specs=(x_spec, P()),
        check=False,
    )(x, weights)
    if cfg.moe_num_shared:
        y = y + mlp(p["shared"], x, cfg.act)
    return y, aux


def _dpsize(mesh, batch_axes_) -> int:
    if isinstance(batch_axes_, str):
        return mesh.shape[batch_axes_]
    n = 1
    for a in batch_axes_ or ():
        n *= mesh.shape[a]
    return n


def moe_layer(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    from repro.distributed.logical import active_rules
    rules = active_rules()
    E = p["w_up"].shape[0]
    if (rules is not None and rules.get("mesh") is not None
            and isinstance(rules.get("experts"), str)
            and cfg.moe_dispatch == "scatter"
            and E % rules["mesh"].shape[rules["experts"]] == 0):
        return moe_shard_map(p, x, cfg, rules)
    fn = moe_scatter if cfg.moe_dispatch == "scatter" else moe_einsum
    return fn(p, x, top_k=cfg.moe_top_k,
              capacity_factor=cfg.moe_capacity_factor, act=cfg.act,
              n_shared=cfg.moe_num_shared)


# ---------------------------------------------------------------------------
# Mamba2 / SSD block
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg) -> Params:
    """Mamba2 weights with *split* projections.

    Upstream fuses (z,x,B,C,dt) into one in_proj and (x,B,C) into one conv.
    We keep them as separate matrices: mathematically identical, but the
    fused layouts concatenate segments whose boundaries are not divisible
    by the 16-way model axis, which would force full replication under TP.
    Split weights let d_inner shard cleanly (see distributed/partition.py).
    """
    d, di = cfg.d_model, cfg.ssm_d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ks = jax.random.split(key, 9)
    return {
        "w_z": dense_init(ks[0], (d, di)),
        "w_x": dense_init(ks[1], (d, di)),
        "w_B": dense_init(ks[2], (d, G * N)),
        "w_C": dense_init(ks[3], (d, G * N)),
        "w_dt": dense_init(ks[4], (d, H)),
        "conv_x": dense_init(ks[5], (cfg.ssm_conv, di), scale=0.5),
        "conv_B": dense_init(ks[6], (cfg.ssm_conv, G * N), scale=0.5),
        "conv_C": dense_init(ks[7], (cfg.ssm_conv, G * N), scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rmsnorm(di),
        "out_proj": dense_init(ks[8], (di, d)),
    }


def causal_conv1d(w: jax.Array, x: jax.Array,
                  tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv via shift-and-sum.  w [k, C]; x [B, S, C].

    ``tail``: [B, k-1, C] carry-in from previous tokens (decode path).
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)        # [B, S+k-1, C]
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + S].astype(jnp.float32) * w[i]
    return jax.nn.silu(out).astype(x.dtype)


def ssd_reference(x, dt, A, B, C, D, *, init_state=None):
    """Sequential SSD recurrence — the ground-truth oracle.

    x [b,l,h,p]; dt [b,l,h]; A [h] (negative); B,C [b,l,g,n] (g=1); D [h].
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t · h_t + D x_t.
    Returns (y [b,l,h,p], final_state [b,h,p,n]).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(hprev, inp):
        xt, dtt, Bt, Ct = inp                       # [b,h,p],[b,h],[b,n],[b,n]
        dA = jnp.exp(dtt * A)                       # [b,h]
        dBx = jnp.einsum("bhp,bn,bh->bhpn", xt.astype(jnp.float32),
                         Bt.astype(jnp.float32), dtt)
        hnew = hprev * dA[..., None, None] + dBx
        yt = jnp.einsum("bhpn,bn->bhp", hnew, Ct.astype(jnp.float32))
        return hnew, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B[:, :, 0], 1, 0), jnp.moveaxis(C[:, :, 0], 1, 0))
    hfin, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), hfin


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 128, init_state=None):
    """Chunked SSD (state-space duality) — the parallel production path.

    Intra-chunk term is attention-like (quadratic in chunk only); inter-chunk
    states pass through a short scan over chunks.  Matches ssd_reference to
    fp32 tolerance (tested).  Returns (y, final_state).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    if l % Q:
        # pad tail with dt=0 tokens: zero decay-rate and zero input, so the
        # final state is unaffected; padded y rows are sliced off below
        pad = Q - l % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, hfin = ssd_chunked(x, dt, A, B, C, D, chunk=chunk,
                              init_state=init_state)
        return y[:, :l], hfin
    nc = l // Q
    xf = x.astype(jnp.float32).reshape(b, nc, Q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, Q, h)
    Bf = B[:, :, 0].astype(jnp.float32).reshape(b, nc, Q, n)
    Cf = C[:, :, 0].astype(jnp.float32).reshape(b, nc, Q, n)

    a = dtf * A[None, None, None, :]                 # [b,nc,Q,h] (negative)
    a_cs = jnp.cumsum(a, axis=2)                     # inclusive
    a_tot = a_cs[:, :, -1]                           # [b,nc,h]

    # intra-chunk: y_q += sum_{k<=q} exp(a_cs_q - a_cs_k) (C_q·B_k) dt_k x_k
    cb = jnp.einsum("bcqn,bckn->bcqk", Cf, Bf)       # [b,nc,Q,Q]
    decay = jnp.exp(a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    w = cb[..., None] * decay                        # [b,nc,Q,Q,h]
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", w, dtf, xf)

    # chunk states: S_c = sum_k exp(a_tot - a_cs_k) dt_k B_k x_k → [b,nc,h,p,n]
    edecay = jnp.exp(a_tot[:, :, None, :] - a_cs)    # [b,nc,Q,h]
    states = jnp.einsum("bckh,bckh,bckhp,bckn->bchpn",
                        edecay, dtf, xf, Bf)

    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def carry(hprev, inp):
        s_c, atot_c = inp                            # [b,h,p,n], [b,h]
        hnew = hprev * jnp.exp(atot_c)[:, :, None, None] + s_c
        return hnew, hprev                           # emit state *entering* c

    hfin, h_in = lax.scan(carry, h0,
                          (jnp.moveaxis(states, 1, 0),
                           jnp.moveaxis(a_tot, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                  # [b,nc,h,p,n]

    # inter-chunk: y_q += C_q · h_in * exp(a_cs_q)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cf, jnp.exp(a_cs), h_in)

    y = (y_intra + y_inter).reshape(b, l, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), hfin


def mamba2_block(p: Params, x: jax.Array, cfg, *,
                 ssm_state=None, conv_tail=None, return_state: bool = False):
    """Full Mamba2 sublayer.  x [B,S,d] → y [B,S,d] (+ cache updates).

    ``conv_tail``: dict {x,B,C} of [B, k-1, ·] carry-ins (or None).
    """
    B_, S, d = x.shape
    di, H = cfg.ssm_d_inner, cfg.ssm_heads
    N, G, P = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    z = constrain(x @ p["w_z"].astype(x.dtype), "batch", None, "inner")
    xin = constrain(x @ p["w_x"].astype(x.dtype), "batch", None, "inner")
    Bc = x @ p["w_B"].astype(x.dtype)
    Cc = x @ p["w_C"].astype(x.dtype)
    dt_raw = constrain(x @ p["w_dt"].astype(x.dtype),
                       "batch", None, "ssm_heads")
    km1 = cfg.ssm_conv - 1
    new_tail = ({"x": xin[:, -km1:], "B": Bc[:, -km1:], "C": Cc[:, -km1:]}
                if return_state else None)
    tails = conv_tail or {"x": None, "B": None, "C": None}
    xin = causal_conv1d(p["conv_x"], xin, tail=tails["x"])
    Bc = causal_conv1d(p["conv_B"], Bc, tail=tails["B"])
    Cc = causal_conv1d(p["conv_C"], Cc, tail=tails["C"])

    xh = constrain(xin.reshape(B_, S, H, P), "batch", None, "ssm_heads",
                   None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    Bm = Bc.reshape(B_, S, G, N)
    Cm = Cc.reshape(B_, S, G, N)
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, p["D"],
                                 chunk=cfg.ssm_chunk, init_state=ssm_state)
    y = y.reshape(B_, S, di)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, final_state, new_tail
    return out


def _conv_decode(w: jax.Array, tail: jax.Array, new: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """One-token depthwise conv: (out [B,1,C], new_tail [B,k-1,C])."""
    full = jnp.concatenate([tail, new], axis=1)             # [B,k,C]
    out = jax.nn.silu(
        jnp.sum(full.astype(jnp.float32) * w[None], axis=1, keepdims=True)
    ).astype(new.dtype)
    return out, full[:, 1:]


def mamba2_decode_step(p: Params, x: jax.Array, cfg, *,
                       ssm_state: jax.Array, conv_tail: Dict[str, jax.Array]):
    """Single-token recurrent update.  x [B,1,d]."""
    B_, _, d = x.shape
    di, H = cfg.ssm_d_inner, cfg.ssm_heads
    N, G, P = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    z = x @ p["w_z"].astype(x.dtype)
    dt_raw = x @ p["w_dt"].astype(x.dtype)
    xin, tail_x = _conv_decode(p["conv_x"], conv_tail["x"],
                               x @ p["w_x"].astype(x.dtype))
    Bc, tail_B = _conv_decode(p["conv_B"], conv_tail["B"],
                              x @ p["w_B"].astype(x.dtype))
    Cc, tail_C = _conv_decode(p["conv_C"], conv_tail["C"],
                              x @ p["w_C"].astype(x.dtype))
    new_tail = {"x": tail_x, "B": tail_B, "C": tail_C}

    xh = xin.reshape(B_, H, P)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    Bm = Bc.reshape(B_, G, N)[:, 0]
    Cm = Cc.reshape(B_, G, N)[:, 0]
    dA = jnp.exp(dt * A)                                    # [B,H]
    dBx = jnp.einsum("bhp,bn,bh->bhpn", xh.astype(jnp.float32),
                     Bm.astype(jnp.float32), dt)
    hnew = ssm_state.astype(jnp.float32) * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", hnew, Cm.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, 1, di).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, hnew, new_tail


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def init_embed(key, V: int, d: int) -> Params:
    return {"table": embed_init(key, (V, d))}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def unembed(table: jax.Array, x: jax.Array, dtype) -> jax.Array:
    return (x @ table.T.astype(x.dtype)).astype(dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL.  logits [B,S,V] (any float dtype), labels [B,S]."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_loss(table: jax.Array, x: jax.Array, labels: jax.Array,
                 chunk: int, logits_dtype) -> jax.Array:
    """Cross-entropy without materializing [B,S,V]: scan over S chunks.

    The memory lever for vocab≈150k at long sequence (see §Perf).
    """
    B, S, d = x.shape
    if chunk <= 0 or S <= chunk:
        return cross_entropy(unembed(table, x, logits_dtype), labels)
    assert S % chunk == 0
    nc = S // chunk
    xs = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(tot, inp):
        xc, lc = inp
        logits = unembed(table, xc, logits_dtype)
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return tot / (B * S)
