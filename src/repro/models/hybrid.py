"""Jamba-style hybrid: Mamba+attention 1:7 interleave with interleaved MoE.

Layer pattern (period ``attn_every`` = 8): attention at block-local index
``attn_offset`` (4), Mamba elsewhere; MoE MLP on odd layers, dense on even.
Jamba uses no positional encoding (the SSM layers carry position), so
``use_rope=False``.

Parameters are organized as *superblocks*: the layer stacks inside one
period are stacked across periods and driven by one ``lax.scan`` — the same
compile-size trick as the dense transformer, despite the mixed layer types.
The per-type KV/SSM caches avoid the 8x memory waste a uniform [L,...] KV
cache would cost on a model where only 1 in 8 layers is attention.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig

Params = Dict[str, Any]


def _pattern(cfg: ModelConfig) -> List[Tuple[str, bool]]:
    """Block-local sublayer pattern: [(mixer, is_moe), ...] of length P."""
    P = cfg.attn_every
    out = []
    for j in range(P):
        mixer = "attn" if j % P == cfg.attn_offset else "ssm"
        out.append((mixer, cfg.is_moe_layer(j)))
    return out


def _counts(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    pat = _pattern(cfg)
    n_ssm = sum(m == "ssm" for m, _ in pat)
    n_attn = len(pat) - n_ssm
    n_moe = sum(moe for _, moe in pat)
    n_dense = len(pat) - n_moe
    return n_ssm, n_attn, n_dense, n_moe


def init(cfg: ModelConfig, key) -> Params:
    assert cfg.num_layers % cfg.attn_every == 0, (cfg.num_layers,
                                                  cfg.attn_every)
    nb = cfg.num_layers // cfg.attn_every
    pat = _pattern(cfg)
    keys = jax.random.split(key, cfg.num_layers * 2 + 2)

    def init_superblock(b: int) -> Params:
        mamba, attn, dense, moe = [], [], [], []
        ln1, ln2 = [], []
        for j, (mixer, is_moe) in enumerate(pat):
            gi = b * cfg.attn_every + j
            k1, k2 = keys[2 * gi], keys[2 * gi + 1]
            ln1.append(L.init_rmsnorm(cfg.d_model)["scale"])
            ln2.append(L.init_rmsnorm(cfg.d_model)["scale"])
            if mixer == "ssm":
                mamba.append(L.init_mamba2(k1, cfg))
            else:
                attn.append(L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                             cfg.num_kv_heads, cfg.hd,
                                             cfg.qk_norm))
            if is_moe:
                moe.append(L.init_moe(k2, cfg.d_model,
                                      cfg.moe_num_experts,
                                      cfg.moe_d_ff or cfg.d_ff,
                                      cfg.moe_num_shared, cfg.act))
            else:
                dense.append(L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act))
        stack = lambda xs: jax.tree_util.tree_map(
            lambda *a: jnp.stack(a), *xs) if xs else {}
        return {
            "mamba": stack(mamba), "attn": stack(attn),
            "mlp": stack(dense), "moe": stack(moe),
            "ln1": jnp.stack(ln1), "ln2": jnp.stack(ln2),
        }

    blocks = [init_superblock(b) for b in range(nb)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": L.init_embed(keys[-1], cfg.vocab_size, cfg.d_model),
        "blocks": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "unembed": {"table": L.embed_init(keys[-2],
                                          (cfg.vocab_size, cfg.d_model))},
    }


def unembed_table(params: Params) -> jax.Array:
    return (params.get("unembed") or params["embed"])["table"]


def _superblock(cfg: ModelConfig, p: Params, x: jax.Array,
                positions: jax.Array, collect: bool):
    """Apply one period of sublayers.  Returns (x, aux, caches)."""
    pat = _pattern(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    i_ssm = i_attn = i_dense = i_moe = 0
    kv = None
    states, tails = [], []
    at = lambda tree, i: jax.tree_util.tree_map(lambda a: a[i], tree)
    for j, (mixer, is_moe) in enumerate(pat):
        h = L.rms_norm({"scale": p["ln1"][j]}, x, cfg.norm_eps)
        if mixer == "ssm":
            pm = at(p["mamba"], i_ssm)
            i_ssm += 1
            if collect:
                y, st, tl = L.mamba2_block(pm, h, cfg, return_state=True)
                states.append(st)
                tails.append(tl)
            else:
                y = L.mamba2_block(pm, h, cfg)
        else:
            pa = at(p["attn"], i_attn)
            i_attn += 1
            q, k, v = L._qkv(pa, h, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                             cfg.qk_norm, cfg.norm_eps)
            q = L.apply_rope(q, positions, cfg.rope_theta,
                             cfg.mrope_sections, cfg.use_rope)
            k = L.apply_rope(k, positions, cfg.rope_theta,
                             cfg.mrope_sections, cfg.use_rope)
            o = L.flash_attention_xla(q, k, v, causal=True,
                                      chunk_q=cfg.attn_chunk_q,
                                      chunk_k=cfg.attn_chunk_k,
                                      causal_skip=cfg.causal_skip)
            B, S = x.shape[:2]
            y = o.reshape(B, S, cfg.num_heads * cfg.hd) @ \
                pa["wo"].astype(x.dtype)
            if collect:
                kv = (k, v)
        x = x + y
        h = L.rms_norm({"scale": p["ln2"][j]}, x, cfg.norm_eps)
        if is_moe:
            m, aux = L.moe_layer(at(p["moe"], i_moe), h, cfg)
            i_moe += 1
            aux_total = aux_total + aux
        else:
            m = L.mlp(at(p["mlp"], i_dense), h, cfg.act)
            i_dense += 1
        x = x + m
    caches = None
    if collect:
        caches = {"kv": kv,
                  "state": jnp.stack(states),      # [n_ssm,B,H,P,N]
                  "conv": jax.tree_util.tree_map(
                      lambda *a: jnp.stack(a), *tails)}  # {x,B,C} [n_ssm,...]
    return x, aux_total, caches


def hidden(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
           collect: bool = False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(x, p):
        x, aux, caches = _superblock(cfg, p, x, positions, collect)
        return x, (aux, caches)

    if cfg.remat == "full":
        block = jax.checkpoint(block)
    x, (aux, caches) = lax.scan(block, x, params["blocks"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.sum(aux), caches


def logits(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    return L.unembed(unembed_table(params), h,
                     jnp.dtype(cfg.logits_dtype)), aux


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate([batch["tokens"][:, 1:],
                                  batch["tokens"][:, -1:]], axis=1)
    nll = L.chunked_loss(unembed_table(params), h, labels,
                         cfg.loss_chunk, jnp.dtype(cfg.logits_dtype))
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    nb = cfg.num_layers // cfg.attn_every
    n_ssm, n_attn, _, _ = _counts(cfg)
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di, gn = cfg.ssm_d_inner, cfg.ssm_groups * cfg.ssm_state
    km1 = cfg.ssm_conv - 1
    return {
        "k": jnp.zeros((nb, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((nb, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
        "state": jnp.zeros((nb, n_ssm, batch, H, P, N), jnp.float32),
        "conv": {"x": jnp.zeros((nb, n_ssm, batch, km1, di), dtype),
                 "B": jnp.zeros((nb, n_ssm, batch, km1, gn), dtype),
                 "C": jnp.zeros((nb, n_ssm, batch, km1, gn), dtype)},
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            cache: Dict[str, Any]):
    h, _aux, caches = hidden(cfg, params, batch, collect=True)
    k, v = caches["kv"]                              # [nb,B,S,K,hd]
    S = batch["tokens"].shape[1]
    out_cache = dict(cache)
    out_cache["k"] = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
    out_cache["v"] = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
    out_cache["state"] = caches["state"].astype(cache["state"].dtype)
    out_cache["conv"] = jax.tree_util.tree_map(
        lambda t, c: t.astype(c.dtype), caches["conv"], cache["conv"])
    out_cache["pos"] = jnp.asarray(S, jnp.int32)
    out = L.unembed(unembed_table(params), h[:, -1:],
                    jnp.dtype(cfg.logits_dtype))
    return out, out_cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, Any]):
    B = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    pat = _pattern(cfg)
    at = lambda tree, i: jax.tree_util.tree_map(lambda a: a[i], tree)

    def block(x, inp):
        p, k_c, v_c, st, cv = inp
        i_ssm = i_attn = i_dense = i_moe = 0
        st_new, cv_new = [], []
        for j, (mixer, is_moe) in enumerate(pat):
            h = L.rms_norm({"scale": p["ln1"][j]}, x, cfg.norm_eps)
            if mixer == "ssm":
                pm = at(p["mamba"], i_ssm)
                tail_i = jax.tree_util.tree_map(lambda a: a[i_ssm], cv)
                y, s_n, t_n = L.mamba2_decode_step(
                    pm, h, cfg, ssm_state=st[i_ssm], conv_tail=tail_i)
                st_new.append(s_n.astype(st.dtype))
                cv_new.append(jax.tree_util.tree_map(
                    lambda a, b: a.astype(b.dtype), t_n, tail_i))
                i_ssm += 1
            else:
                pa = at(p["attn"], i_attn)
                i_attn += 1
                q, k, v = L._qkv(pa, h, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.hd, cfg.qk_norm, cfg.norm_eps)
                q = L.apply_rope(q, positions, cfg.rope_theta,
                                 cfg.mrope_sections, cfg.use_rope)
                k = L.apply_rope(k, positions, cfg.rope_theta,
                                 cfg.mrope_sections, cfg.use_rope)
                k_c = lax.dynamic_update_slice_in_dim(
                    k_c, k.astype(k_c.dtype), pos, axis=1)
                v_c = lax.dynamic_update_slice_in_dim(
                    v_c, v.astype(v_c.dtype), pos, axis=1)
                o = L.decode_attention(q, k_c, v_c, pos + 1)
                y = o.reshape(B, 1, cfg.num_heads * cfg.hd) @ \
                    pa["wo"].astype(x.dtype)
            x = x + y
            h = L.rms_norm({"scale": p["ln2"][j]}, x, cfg.norm_eps)
            if is_moe:
                m, _ = L.moe_layer(at(p["moe"], i_moe), h, cfg)
                i_moe += 1
            else:
                m = L.mlp(at(p["mlp"], i_dense), h, cfg.act)
                i_dense += 1
            x = x + m
        return x, (k_c, v_c, jnp.stack(st_new),
                   jax.tree_util.tree_map(lambda *a: jnp.stack(a), *cv_new))

    x, (k_new, v_new, st_new, cv_new) = lax.scan(
        block, x, (params["blocks"], cache["k"], cache["v"],
                   cache["state"], cache["conv"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    out = L.unembed(unembed_table(params), x, jnp.dtype(cfg.logits_dtype))
    return out, {"k": k_new, "v": v_new, "state": st_new, "conv": cv_new,
                 "pos": pos + 1}
