"""Model configuration — one dataclass covering every assigned family.

Families: dense / moe / ssm / hybrid / encdec / vlm / audio.  A config is a
frozen value object; ``src/repro/configs/<arch>.py`` files instantiate the
exact assigned architectures, and ``reduced()`` derives the CPU-smoke-test
variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Dict, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // num_heads

    # --- norms / misc ---
    qk_norm: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm (whisper)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"               # silu (SwiGLU) | gelu (plain MLP)
    max_seq: int = 32768            # learned-position table size (encdec)

    # --- rotary ---
    use_rope: bool = True           # jamba: no positional encoding at all
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t,h,w) split

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0               # per-expert ffn dim (fine-grained MoE)
    moe_every: int = 1              # MoE on layers where (i % moe_every)==moe_offset
    moe_offset: int = 0
    moe_first_dense: int = 0        # first k layers use a dense MLP
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"   # scatter | einsum (reference)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- hybrid (jamba) ---
    attn_every: int = 0             # attention on layers where (i % attn_every)==attn_offset
    attn_offset: int = 4

    # --- encoder-decoder (whisper) ---
    num_enc_layers: int = 0
    enc_seq: int = 1500             # precomputed-frame count (frontend stub)
    learned_pos: bool = False

    # --- modality frontend stubs ---
    frontend: str = "none"          # none | audio_frames | vision_patches

    # --- numerics / implementation knobs (perf levers, not architecture) ---
    dtype: str = "bfloat16"
    attn_impl: str = "flash_xla"    # flash_xla | naive | flash_pallas
    attn_chunk_q: int = 512
    attn_chunk_k: int = 512
    causal_skip: bool = True        # skip fully-masked k-chunks (triangular sched)
    loss_chunk: int = 0             # 0 = unchunked cross-entropy
    remat: str = "none"             # none | full | dots
    scan_layers: bool = True
    logits_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_num_experts == 0 or i < self.moe_first_dense:
            return False
        return (i % self.moe_every) == self.moe_offset

    def is_attn_layer(self, i: int) -> bool:
        """hybrid: which layers are attention (rest are SSM)."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return (i % self.attn_every) == self.attn_offset

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear-attention)."""
        return self.family in ("ssm", "hybrid")

    # -- parameter counting (exact, used for 6·N·D roofline) -------------
    def param_counts(self) -> Dict[str, int]:
        d, hd = self.d_model, self.hd
        H, K, V = self.num_heads, self.num_kv_heads, self.vocab_size
        counts: Dict[str, int] = {"embed": V * d}
        if not self.tie_embeddings:
            counts["unembed"] = V * d
        attn = d * H * hd + 2 * d * K * hd + H * hd * d   # q,k,v,o
        if self.qk_norm:
            attn += 2 * hd
        dense_mlp = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        moe_ff = self.moe_d_ff or self.d_ff
        expert = 3 * d * moe_ff if self.act == "silu" else 2 * d * moe_ff
        moe_mlp = (self.moe_num_experts * expert
                   + self.moe_num_shared * expert
                   + d * self.moe_num_experts)            # router
        di, N, G = self.ssm_d_inner, self.ssm_state, self.ssm_groups
        nheads = self.ssm_heads if self.ssm_state else 0
        ssm = (d * (2 * di + 2 * G * N + nheads)          # in_proj
               + self.ssm_conv * (di + 2 * G * N)         # depthwise conv
               + nheads * 2                               # A_log, D
               + nheads                                   # dt_bias
               + di                                       # gated norm
               + di * d) if self.ssm_state else 0         # out_proj

        total_layers = 0
        n_layers = self.num_layers
        per_layer = []
        for i in range(n_layers):
            layer = 2 * d                                  # 2 norms
            if self.family == "ssm":
                layer += ssm
            elif self.family == "hybrid":
                layer += ssm if not self.is_attn_layer(i) else attn
                layer += moe_mlp if self.is_moe_layer(i) else dense_mlp
            else:
                layer += attn
                layer += moe_mlp if self.is_moe_layer(i) else dense_mlp
            per_layer.append(layer)
            total_layers += layer
        if self.num_enc_layers:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.num_enc_layers * (attn + dense_mlp + 2 * d)
            dec_cross = n_layers * (attn + d)
            counts["encoder"] = enc
            counts["cross_attn"] = dec_cross
            total_layers += dec_cross
            counts["enc_total"] = enc
        counts["layers"] = total_layers
        counts["final_norm"] = d
        counts["total"] = sum(v for k, v in counts.items()
                              if k not in ("layers", "enc_total", "encoder",
                                           "cross_attn", "total")) \
            + total_layers + (counts.get("encoder", 0))
        return counts

    def num_params(self) -> int:
        return self.param_counts()["total"]

    def num_active_params(self) -> int:
        """Active per-token params (MoE: top-k + shared only)."""
        if self.moe_num_experts == 0:
            return self.num_params()
        moe_ff = self.moe_d_ff or self.d_ff
        expert = (3 if self.act == "silu" else 2) * self.d_model * moe_ff
        inactive_experts = self.moe_num_experts - self.moe_top_k
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        return self.num_params() - n_moe_layers * inactive_experts * expert

    # -- reduced config for CPU smoke tests ------------------------------
    def reduced(self) -> "ModelConfig":
        small: Dict[str, object] = dict(
            num_layers=min(self.num_layers, 4 if self.family != "hybrid"
                           else max(self.attn_every, 4)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=256,
            attn_chunk_q=64, attn_chunk_k=64,
            loss_chunk=0,
        )
        if self.mrope_sections:
            # keep 3 sections summing to new head_dim/2
            half = 32 // 2
            small["mrope_sections"] = (half - 2 * (half // 3),
                                       half // 3, half // 3)
        if self.moe_num_experts:
            small.update(moe_num_experts=4, moe_top_k=2,
                         moe_num_shared=min(self.moe_num_shared, 1),
                         moe_d_ff=64 if self.moe_d_ff else 0,
                         moe_first_dense=min(self.moe_first_dense, 1))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.num_enc_layers:
            small.update(num_enc_layers=2, enc_seq=32)
        if self.family == "hybrid":
            small.update(num_layers=8, attn_every=min(self.attn_every, 8))
        return replace(self, **small)

    def override(self, **kwargs) -> "ModelConfig":
        return replace(self, **kwargs)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# Registry of architecture configs (populated by repro.configs modules).
_ARCH_REGISTRY: Dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _ARCH_REGISTRY:
        raise ValueError(f"arch {cfg.name!r} already registered")
    _ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate lazily so `import repro.models.config` stays cheap
    if not _ARCH_REGISTRY:
        import repro.configs  # noqa: F401  (registers all archs)
    if name not in _ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have "
                       f"{sorted(_ARCH_REGISTRY)}")
    return _ARCH_REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    if not _ARCH_REGISTRY:
        import repro.configs  # noqa: F401
    return tuple(sorted(_ARCH_REGISTRY))
