"""Unified model API — one surface over all families.

``build(cfg)`` returns a :class:`ModelApi` whose members close over the
config; the launch/train/serve layers and the model_scope benchmarks only
ever talk to this surface, never to family modules directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from . import encdec, hybrid, ssm, transformer
from .config import ModelConfig

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
    "audio": encdec,
}


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[jax.Array], Dict]
    loss: Callable[[Dict, Dict], Any]            # (params, batch) -> (loss, metrics)
    logits: Callable[[Dict, Dict], Any]
    init_cache: Callable[..., Dict]
    prefill: Callable[[Dict, Dict, Dict], Any]   # (params, batch, cache)
    decode_step: Callable[[Dict, jax.Array, Dict], Any]
    unembed_table: Callable[[Dict], jax.Array]


def family_module(cfg: ModelConfig):
    if cfg.family not in _FAMILIES:
        raise KeyError(f"unknown family {cfg.family!r}")
    return _FAMILIES[cfg.family]


def build(cfg: ModelConfig) -> ModelApi:
    mod = family_module(cfg)
    return ModelApi(
        cfg=cfg,
        init=lambda key: mod.init(cfg, key),
        loss=lambda params, batch: mod.loss(cfg, params, batch),
        logits=lambda params, batch: mod.logits(cfg, params, batch),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
            mod.init_cache(cfg, batch, max_len, dtype),
        prefill=lambda params, batch, cache, **kw: mod.prefill(
            cfg, params, batch, cache, **kw),
        decode_step=lambda params, tokens, cache:
            mod.decode_step(cfg, params, tokens, cache),
        unembed_table=mod.unembed_table,
    )
