"""Decoder-only transformer LM — dense, MoE, and VLM-stub variants.

Covers llama3.2-1b, qwen3-1.7b, internlm2-1.8b, stablelm-12b (dense),
moonshot-v1-16b-a3b, deepseek-moe-16b (MoE), qwen2-vl-2b (VLM backbone with
M-RoPE and stubbed vision embeddings).

Layers are stacked along a leading axis and driven by ``lax.scan`` so the
HLO is one while-loop regardless of depth — this is what keeps the
512-device dry-run compile tractable and the remat policy uniform.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, moe: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.hd, cfg.qk_norm),
    }
    if moe:
        p["moe"] = L.init_moe(k2, cfg.d_model, cfg.moe_num_experts,
                              cfg.moe_d_ff or cfg.d_ff,
                              cfg.moe_num_shared, cfg.act)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 2)
    moe = cfg.moe_num_experts > 0
    blocks = [_init_block(keys[i], cfg, moe and cfg.is_moe_layer(i))
              for i in range(cfg.num_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    p: Params = {
        "embed": L.init_embed(keys[-1], cfg.vocab_size, cfg.d_model),
        "blocks": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"table": L.embed_init(keys[-2],
                                              (cfg.vocab_size, cfg.d_model))}
    return p


def unembed_table(params: Params) -> jax.Array:
    return (params.get("unembed") or params["embed"])["table"]


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                 positions: jax.Array, collect_kv: bool):
    """One transformer block.  Returns (x, aux, (k, v) | None)."""
    h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
    q, k, v = L._qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                     cfg.qk_norm, cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections,
                     cfg.use_rope)
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections,
                     cfg.use_rope)
    if cfg.attn_impl == "naive":
        o = L.naive_attention(q, k, v, causal=True)
    else:
        o = L.flash_attention_xla(q, k, v, causal=True,
                                  chunk_q=cfg.attn_chunk_q,
                                  chunk_k=cfg.attn_chunk_k,
                                  causal_skip=cfg.causal_skip)
    B, S = x.shape[:2]
    x = x + o.reshape(B, S, cfg.num_heads * cfg.hd) @ \
        p["attn"]["wo"].astype(x.dtype)

    h = L.rms_norm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        m, aux = L.moe_layer(p["moe"], h, cfg)
    else:
        m, aux = L.mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    x = x + m
    return x, aux, ((k, v) if collect_kv else None)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
                  ) -> Tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_patches" and "vision_embeds" in batch:
        # stubbed multimodal merge: precomputed patch embeddings replace
        # the token embeddings at masked positions (qwen2-vl style)
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.where(batch["vision_mask"][..., None], ve, x)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    return x, positions


def hidden(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
           collect_kv: bool = False):
    """Run the block stack.  Returns (h, aux, kv|None).

    kv (prefill): (k, v) stacked [L, B, S, K, hd].
    """
    x, positions = _embed_inputs(cfg, params, batch)

    def block(x, p):
        x, aux, kv = _block_apply(cfg, p, x, positions, collect_kv)
        return x, (aux, kv)

    block = _maybe_remat(block, cfg)
    if cfg.scan_layers:
        x, (aux, kv) = lax.scan(block, x, params["blocks"])
        aux = jnp.sum(aux)
    else:
        auxs, ks, vs = [], [], []
        for i in range(cfg.num_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, (a, kv_i) = block(x, p_i)
            auxs.append(a)
            if collect_kv:
                ks.append(kv_i[0])
                vs.append(kv_i[1])
        aux = jnp.sum(jnp.stack(auxs))
        kv = (jnp.stack(ks), jnp.stack(vs)) if collect_kv else None
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, kv


def logits(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    h, aux, _ = hidden(cfg, params, batch)
    out = L.unembed(unembed_table(params), h, jnp.dtype(cfg.logits_dtype))
    return out, aux


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """Next-token cross-entropy (+ MoE aux), seq-chunked when configured."""
    h, aux, _ = hidden(cfg, params, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate([batch["tokens"][:, 1:],
                                  batch["tokens"][:, -1:]], axis=1)
    nll = L.chunked_loss(unembed_table(params), h, labels,
                         cfg.loss_chunk, jnp.dtype(cfg.logits_dtype))
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    K, hd, Ln = cfg.num_kv_heads, cfg.hd, cfg.num_layers
    return {
        "k": jnp.zeros((Ln, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((Ln, batch, max_len, K, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            cache: Dict[str, Any], logit_pos=None):
    """Process the prompt; fill the cache; return last-position logits.

    ``logit_pos``: position whose logits to return (traced scalar ok) —
    the serve engine passes len(prompt)-1 for right-padded prompts.
    """
    h, _aux, kv = hidden(cfg, params, batch, collect_kv=True)
    k, v = kv                                       # [L,B,S,K,hd]
    S = k.shape[2]
    cache = dict(cache)
    cache["k"] = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    if logit_pos is None:
        h_last = h[:, -1:]
    else:
        h_last = lax.dynamic_slice_in_dim(h, logit_pos, 1, axis=1)
    out = L.unembed(unembed_table(params), h_last,
                    jnp.dtype(cfg.logits_dtype))
    return out, cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, Any]):
    """One decode step.  tokens [B,1] → (logits [B,1,V], updated cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))

    def block(x, inp):
        p, k_c, v_c = inp
        h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                         cfg.hd, cfg.qk_norm, cfg.norm_eps)
        q = L.apply_rope(q, positions, cfg.rope_theta,
                         cfg.mrope_sections, cfg.use_rope)
        k = L.apply_rope(k, positions, cfg.rope_theta,
                         cfg.mrope_sections, cfg.use_rope)
        k_c = lax.dynamic_update_slice_in_dim(
            k_c, k.astype(k_c.dtype), pos, axis=1)
        v_c = lax.dynamic_update_slice_in_dim(
            v_c, v.astype(v_c.dtype), pos, axis=1)
        o = L.decode_attention(q, k_c, v_c, pos + 1)
        x = x + o.reshape(B, 1, cfg.num_heads * cfg.hd) @ \
            p["attn"]["wo"].astype(x.dtype)
        h = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            m, _ = L.moe_layer(p["moe"], h, cfg)
        else:
            m = L.mlp(p["mlp"], h, cfg.act)
        return x + m, (k_c, v_c)

    x, (k_new, v_new) = lax.scan(
        block, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    out = L.unembed(unembed_table(params), x, jnp.dtype(cfg.logits_dtype))
    cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return out, cache


def decode_step_ragged(cfg: ModelConfig, params: Params, tokens: jax.Array,
                       cache: Dict[str, Any]):
    """Decode with PER-ROW positions — the continuous-batching path.

    ``cache['pos']`` is [B]: each slot writes its k/v at its own offset
    (scatter) and masks to its own prefix.  Used by the serve engine where
    slots hold requests admitted at different times; the uniform-batch
    ``decode_step`` remains the production multi-pod path (per-row scatter
    onto a sequence-sharded cache would defeat the cache sharding).
    """
    B = tokens.shape[0]
    pos = cache["pos"]                                   # [B]
    bidx = jnp.arange(B)
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = pos[:, None]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))

    def block(x, inp):
        p, k_c, v_c = inp
        h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                         cfg.hd, cfg.qk_norm, cfg.norm_eps)
        q = L.apply_rope(q, positions, cfg.rope_theta,
                         cfg.mrope_sections, cfg.use_rope)
        k = L.apply_rope(k, positions, cfg.rope_theta,
                         cfg.mrope_sections, cfg.use_rope)
        k_c = k_c.at[bidx, pos].set(k[:, 0].astype(k_c.dtype))
        v_c = v_c.at[bidx, pos].set(v[:, 0].astype(v_c.dtype))
        o = L.decode_attention(q, k_c, v_c, pos + 1)
        x = x + o.reshape(B, 1, cfg.num_heads * cfg.hd) @ \
            p["attn"]["wo"].astype(x.dtype)
        h = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            m, _ = L.moe_layer(p["moe"], h, cfg)
        else:
            m = L.mlp(p["mlp"], h, cfg.act)
        return x + m, (k_c, v_c)

    x, (k_new, v_new) = lax.scan(
        block, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    out = L.unembed(unembed_table(params), x, jnp.dtype(cfg.logits_dtype))
    return out, {"k": k_new, "v": v_new, "pos": pos + 1}
