"""repro.models — pure-JAX composable model zoo (the system under test)."""
from .api import ModelApi, build, family_module
from .config import ModelConfig, get_config, list_archs, register_arch

__all__ = ["ModelApi", "ModelConfig", "build", "family_module",
           "get_config", "list_archs", "register_arch"]
