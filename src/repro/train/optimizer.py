"""AdamW + schedules in pure JAX (optax is not available offline).

State layout mirrors optax: ``{"m": tree, "v": tree, "count": i32[]}`` so
checkpoints stay tool-agnostic.  All moments are fp32 regardless of param
dtype; weight decay is decoupled (AdamW); a global-norm clip runs upstream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def warmup_cosine(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)
    return schedule


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, grads, opt_state, params,
                 schedule: Optional[Callable] = None):
    """One AdamW step.  Returns (new_params, new_opt_state, lr)."""
    count = opt_state["count"] + 1
    lr = (schedule or warmup_cosine(cfg))(count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                   # no decay on norms/biases/scalars
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new, v_new

    flat = jax.tree_util.tree_map(upd, grads, opt_state["m"],
                                  opt_state["v"], params)
    # unzip the 3-tuples
    p_new = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return p_new, {"m": m_new, "v": v_new, "count": count}, lr
