"""repro.train — optimizer, schedules, train-step factory."""
from .optimizer import (AdamWConfig, adamw_init, adamw_update,
                        clip_by_global_norm, warmup_cosine)
from .step import TrainState, make_init_fn, make_train_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "clip_by_global_norm", "warmup_cosine",
           "TrainState", "make_init_fn", "make_train_step"]
