"""Train-step factory: loss → grads → clip → AdamW, as one SPMD program.

The returned ``train_step(state, batch)`` is pjit-compatible: all
distribution comes from in/out shardings supplied by the launch layer.
Gradient accumulation (microbatching) is a ``lax.scan`` over batch slices so
compute/comm overlap falls out of XLA's scheduler: the all-reduce of
microbatch k overlaps the backward of microbatch k+1.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.api import ModelApi
from .optimizer import (AdamWConfig, adamw_init, adamw_update,
                        clip_by_global_norm, warmup_cosine)

TrainState = Dict[str, Any]      # {"params", "opt": {m,v,count}, "step"}


def make_init_fn(api: ModelApi, opt_cfg: AdamWConfig
                 ) -> Callable[[jax.Array], TrainState]:
    def init_fn(key) -> TrainState:
        params = api.init(key)
        return {"params": params, "opt": adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}
    return init_fn


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    """[B, ...] → [n, B/n, ...] per leaf (positions [3,B,S] handled)."""
    def split(x):
        if x.ndim >= 3 and x.shape[0] == 3:          # M-RoPE positions
            return x.reshape(3, n, x.shape[1] // n,
                             *x.shape[2:]).swapaxes(0, 1)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(api: ModelApi, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1, grad_specs=None):
    """``grad_specs``: optional PartitionSpec tree for the gradient
    accumulator.  CRITICAL at scale: a replicated-over-data accumulator
    forces XLA to ALL-REDUCE the full gradients once per microbatch
    (observed 507 GB/device/step on jamba train_4k, 16 microbatches).
    Zero-sharded (ZeRO-style) accumulation turns each microbatch's sync
    into a reduce-scatter at 1/|data| the bytes — ~16x less gradient
    traffic (EXPERIMENTS.md §Perf C3)."""
    schedule = warmup_cosine(opt_cfg)

    def loss_fn(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain_grads(g):
        if grad_specs is None:
            return g
        return jax.tree_util.tree_map(
            lambda a, sp: jax.lax.with_sharding_constraint(a, sp), g,
            grad_specs)

    def train_step(state: TrainState, batch: Dict[str, Any]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params = state["params"]
        if num_microbatches > 1:
            micro = _split_microbatches(batch, num_microbatches)

            def accum(carry, mb):
                gsum, lsum = carry
                (l, _m), g = grad_fn(params, mb)
                # constrain THE GRADIENT (not the sum): the partitioner
                # then lowers the pending batch-psum directly into a
                # reduce-scatter instead of all-reduce + slice
                g = _constrain_grads(g)
                gsum = _constrain_grads(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g))
                return (gsum, lsum + l), None

            g0 = _constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = lax.scan(accum, (g0, jnp.zeros(())), micro)
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, grads)
            loss = loss / num_microbatches
            metrics = {"nll": loss, "aux": jnp.zeros(())}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt, lr = adamw_update(
            opt_cfg, grads, state["opt"], params, schedule)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                       **{k: v for k, v in metrics.items()}}
        return new_state, out_metrics

    return train_step


def make_eval_step(api: ModelApi):
    def eval_step(params, batch):
        loss, metrics = api.loss(params, batch)
        return {"loss": loss, **metrics}
    return eval_step
