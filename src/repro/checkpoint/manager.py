"""CheckpointManager: async save, keep-k GC, preemption-safe restart.

Fault-tolerance contract (designed for 1000+ nodes, exercised here with
host_count=1):
  * ``maybe_save`` snapshots device state to host (cheap, synchronous) and
    writes files on a background thread — training never blocks on disk;
  * a save is atomic (tmp + rename, see store.py) and only acknowledged in
    ``latest_step`` once fully on disk;
  * keep-k garbage collection never deletes the newest complete ckpt;
  * ``install_signal_handler`` converts SIGTERM/SIGINT (preemption) into a
    final synchronous save + clean exit — restart resumes exactly;
  * ``restore_or_init`` falls back through checkpoints newest-first,
    skipping any that fail checksum verification (torn writes on a
    crashed host).
"""
from __future__ import annotations

import os
import re
import shutil
import signal
import sys
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.logging import get_logger
from .store import load_checkpoint, save_checkpoint

log = get_logger("ckpt")

_STEP_RE = re.compile(r"step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 save_interval: int = 100, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery ------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.search(name)
            if m and not name.endswith(".tmp"):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def path_for(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}")

    # -- saving ---------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def maybe_save(self, step: int, tree, extra: Optional[Dict] = None,
                   force: bool = False) -> bool:
        if not (force or self.should_save(step)):
            return False
        self.wait()                       # one outstanding save at a time
        # snapshot to host NOW (device buffers may be donated next step)
        host_tree = jax.tree_util.tree_map(
            lambda x: jax.device_get(x) if isinstance(x, jax.Array) else x,
            tree)

        def work():
            try:
                save_checkpoint(self.path_for(step), host_tree, step, extra)
                self._gc()
                log.info("saved checkpoint step %d", step)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self.check()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.path_for(s), ignore_errors=True)
            log.info("gc checkpoint step %d", s)

    # -- restoring ------------------------------------------------------
    def restore_or_init(self, tree_like, init_fn: Callable[[], Any]
                        ) -> Tuple[Any, int]:
        """Newest valid checkpoint, else ``init_fn()`` at step 0."""
        for step in reversed(self.steps()):
            try:
                tree, s = load_checkpoint(self.path_for(step), tree_like)
                log.info("restored checkpoint step %d", s)
                return tree, s
            except Exception as e:  # noqa: BLE001 - fall through older ckpts
                log.warning("checkpoint step %d unusable (%s); trying older",
                            step, e)
        return init_fn(), 0

    # -- preemption -----------------------------------------------------
    def install_signal_handler(self, get_state: Callable[[], Tuple[int, Any]]
                               ) -> None:
        def handler(signum, frame):
            step, tree = get_state()
            log.warning("signal %d: saving step %d before exit", signum, step)
            self.wait()
            self.maybe_save(step, tree, force=True)
            self.wait()
            sys.exit(0)

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)
