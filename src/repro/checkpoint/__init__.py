"""repro.checkpoint — sharded, async, fault-tolerant checkpoints."""
from .manager import CheckpointManager
from .store import load_checkpoint, restore_resharded, save_checkpoint

__all__ = ["CheckpointManager", "load_checkpoint", "restore_resharded",
           "save_checkpoint"]
