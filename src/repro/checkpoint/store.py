"""Sharded checkpoint format: per-leaf .npy files + JSON manifest.

Design for 1000+ nodes:
  * per-shard files — every host writes only ITS device shards
    (``addressable_shards``); no gather-to-host-0, no cross-host traffic;
  * a manifest carries the tree structure, logical shapes, dtypes,
    PartitionSpecs and per-file checksums — restore can therefore reshard
    onto a *different* mesh (elastic restart) because the logical view is
    mesh-independent;
  * writes go to a temp directory + atomic rename: a checkpoint either
    exists completely or not at all (crash-safe);
  * checksums (crc32) guard against torn/corrupt files on restore.

On this single-process container every shard is addressable, so the code
path is the real one with host_count=1.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

MANIFEST = "manifest.json"


def _save_raw(path: str, data: np.ndarray) -> None:
    """Byte-exact storage for ANY dtype (np.save mangles bfloat16 to a
    void dtype): the payload is a uint8 view; dtype/shape live in the
    manifest."""
    np.save(path, np.ascontiguousarray(data).view(np.uint8).reshape(-1))


def _load_raw(path: str, dtype: str, shape) -> np.ndarray:
    raw = np.load(path)
    return raw.view(np.dtype(dtype)).reshape(shape)


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(_key_str(k) for k in path), leaf)
            for path, leaf in flat]


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _leaf_filename(name: str, shard_idx: int) -> str:
    safe = name.replace("/", "__")
    return f"{safe}.shard{shard_idx}.npy"


def save_checkpoint(path: str, tree, step: int = 0,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Write ``tree`` under ``path`` (atomic).  Returns the final path."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": int(step), "leaves": {},
                                "extra": extra or {},
                                "process_count": jax.process_count()}
    for name, leaf in _flatten(tree):
        arr = leaf
        entry: Dict[str, Any] = {
            "shape": list(np.shape(arr)),
            "dtype": str(np.asarray(jax.tree_util.tree_leaves(arr)[0]).dtype
                         if not hasattr(arr, "dtype") else arr.dtype),
            "shards": [],
        }
        if isinstance(arr, jax.Array) and hasattr(arr, "sharding"):
            spec = getattr(arr.sharding, "spec", None)
            entry["partition_spec"] = _spec_to_json(spec)
            for shard in arr.addressable_shards:
                data = np.asarray(shard.data)
                fname = _leaf_filename(name, _shard_key(shard.index,
                                                        arr.shape))
                _save_raw(os.path.join(tmp, fname), data)
                entry["shards"].append({
                    "file": fname,
                    "index": _index_to_json(shard.index, arr.shape),
                    "crc32": zlib.crc32(data.tobytes()) & 0xFFFFFFFF,
                })
        else:
            data = np.asarray(arr)
            fname = _leaf_filename(name, 0)
            _save_raw(os.path.join(tmp, fname), data)
            entry["shards"].append({
                "file": fname,
                "index": _index_to_json(tuple(slice(None) for _ in data.shape),
                                        data.shape),
                "crc32": zlib.crc32(data.tobytes()) & 0xFFFFFFFF,
            })
        manifest["leaves"][name] = entry
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def _shard_key(index, shape) -> int:
    key = 0
    for sl, dim in zip(index, shape):
        start = sl.start or 0
        key = key * (dim + 1) + start
    return key


def _index_to_json(index, shape):
    out = []
    for sl, dim in zip(index, shape):
        out.append([sl.start or 0, sl.stop if sl.stop is not None else dim])
    return out


def _spec_to_json(spec) -> Optional[List[Any]]:
    if spec is None:
        return None
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(e)
    return out


def load_manifest(path: str) -> Dict[str, Any]:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)


def _assemble(path: str, entry: Dict[str, Any],
              verify: bool = True) -> np.ndarray:
    dtype = entry["dtype"]
    full = np.empty(entry["shape"], dtype=np.dtype(dtype))
    if not entry["shape"]:
        sh = entry["shards"][0]
        data = _load_raw(os.path.join(path, sh["file"]), dtype, ())
        _check(sh, data, verify)
        return data
    for sh in entry["shards"]:
        shard_shape = tuple(b - a for a, b in sh["index"])
        data = _load_raw(os.path.join(path, sh["file"]), dtype, shard_shape)
        _check(sh, data, verify)
        idx = tuple(slice(a, b) for a, b in sh["index"])
        full[idx] = data
    return full


def _check(shard_entry, data, verify):
    if verify:
        crc = zlib.crc32(np.ascontiguousarray(data).tobytes()) & 0xFFFFFFFF
        if crc != shard_entry["crc32"]:
            raise IOError(f"checksum mismatch in {shard_entry['file']}: "
                          f"{crc:#x} != {shard_entry['crc32']:#x}")


def load_checkpoint(path: str, tree_like, verify: bool = True):
    """Restore into the structure of ``tree_like`` (host arrays)."""
    manifest = load_manifest(path)
    names = [n for n, _ in _flatten(tree_like)]
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}")
    arrays = {n: _assemble(path, manifest["leaves"][n], verify)
              for n in names}
    leaves = [arrays[n] for n in names]
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


def restore_resharded(path: str, tree_like, mesh: Mesh, spec_tree,
                      verify: bool = True):
    """Elastic restart: place a checkpoint onto a (possibly different) mesh.

    The manifest's logical shapes are mesh-independent; each leaf is
    assembled and re-placed with the *target* mesh/spec — restoring a
    16-device checkpoint onto 8 devices (or 512) is the same code path.
    """
    host_tree, step = load_checkpoint(path, tree_like, verify)

    def place(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    placed = jax.tree_util.tree_map(
        place, host_tree, spec_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
    return placed, step
