"""Deterministic, sharded, resumable synthetic-LM data pipeline.

Production properties the trainer relies on:
  * determinism & resumability — batch ``i`` is a pure function of
    (seed, i); restart at step N replays exactly the stream from N
    (checkpoint stores only the step counter, not pipeline state);
  * host sharding — each host materializes only its ``host_index`` slice
    of the global batch (scales to any host count);
  * background prefetch — a small thread-ahead queue hides generation
    latency behind the device step;
  * packing — documents of random length are packed into fixed (B, S)
    token blocks with EOS separators, the standard LM pretraining layout.

Synthetic text: a mixture of Zipf-distributed unigrams and a Markov chain
over a small state space — enough structure that a ~100M model's loss
visibly drops (examples/train_lm.py), while needing no external data.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_count: int = 1
    host_index: int = 0
    mean_doc_len: int = 256
    eos_id: int = 0
    zipf_a: float = 1.3
    markov_states: int = 64
    prefetch: int = 2


class SyntheticLM:
    """Zipf+Markov token source with per-(seed, step) determinism."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.host_count == 0
        self.local_batch = cfg.global_batch // cfg.host_count
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed transition structure shared by all batches
        self._trans = base.integers(1, v, size=(cfg.markov_states, 8))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._zipf = probs / probs.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index))
        B, S = self.local_batch, cfg.seq_len
        tokens = np.empty((B, S + 1), np.int32)
        for b in range(B):
            tokens[b] = self._pack_row(rng, S + 1)
        return {"tokens": tokens[:, :-1],
                "labels": tokens[:, 1:].copy()}

    def _pack_row(self, rng, length: int) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(length, np.int32)
        pos = 0
        while pos < length:
            doc_len = min(int(rng.exponential(cfg.mean_doc_len)) + 8,
                          length - pos)
            state = int(rng.integers(cfg.markov_states))
            # zipf unigrams with markov "topic" offsets
            uni = rng.choice(cfg.vocab_size, size=doc_len, p=self._zipf)
            mark = self._trans[state, rng.integers(0, 8, size=doc_len)]
            mix = rng.random(doc_len) < 0.5
            doc = np.where(mix, uni, mark).astype(np.int32)
            doc[-1] = cfg.eos_id
            out[pos:pos + doc_len] = doc
            pos += doc_len
        return out


class _Prefetcher:
    def __init__(self, src: SyntheticLM, start_step: int, depth: int):
        self.src = src
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.src.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  prefetch: bool = True):
    """Iterator of (step, {tokens, labels}) from ``start_step``."""
    src = SyntheticLM(cfg)
    if prefetch:
        return _Prefetcher(src, start_step, cfg.prefetch)

    def gen():
        step = start_step
        while True:
            yield step, src.batch(step)
            step += 1
    return gen()
