"""repro.data — deterministic sharded synthetic data pipeline."""
from .pipeline import DataConfig, SyntheticLM, make_pipeline

__all__ = ["DataConfig", "SyntheticLM", "make_pipeline"]
