"""repro — SCOPE benchmarking framework reproduction.

Process-wide JAX configuration lives here so every entry point (pytest,
``python -m repro``, orchestrator workers, launch scripts) agrees:

  * ``jax_threefry_partitionable``: without it, the SPMD partitioner
    changes the bits ``jax.random`` produces when an init computation is
    jitted with shardings — sharded model init then silently disagrees
    with single-device init (observed 0.38 max param diff on the 2x4-mesh
    llama train-step equivalence test).  The partitionable generator is
    sharding-invariant; newer JAX enables it by default.
"""
import jax as _jax

try:
    _jax.config.update("jax_threefry_partitionable", True)
except AttributeError:  # removed option on future JAX: already default-on
    pass
