#!/usr/bin/env python
"""Dead-relative-link checker for the Markdown docs tree.

Usage::

    python scripts/check_links.py README.md docs [more files/dirs...]

Scans every Markdown file for inline links/images ``[text](target)``
and reference definitions ``[ref]: target``, and fails (exit 1) when a
*relative* target doesn't exist on disk.  External (``http(s)://``,
``mailto:``) and pure-anchor (``#...``) targets are skipped; a relative
target's ``#fragment`` is stripped before the existence check.

CI runs this over README.md + docs/ so a renamed file can't leave a
dead link behind; ``tests/test_docs.py`` runs the same check in tier-1.
"""
from __future__ import annotations

import os
import re
import sys
from typing import Iterable, List, Tuple

# [text](target) — target up to the first unescaped ')' — and [ref]: target
_INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def markdown_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".md"))
        elif p.endswith(".md"):
            out.append(p)
    return out


def link_targets(text: str) -> List[str]:
    return _INLINE.findall(text) + _REFDEF.findall(text)


def dead_links(md_path: str) -> List[Tuple[str, str]]:
    """(target, reason) for every broken relative link in one file."""
    with open(md_path) as f:
        text = f.read()
    base = os.path.dirname(os.path.abspath(md_path))
    bad: List[Tuple[str, str]] = []
    for target in link_targets(text):
        if target.startswith(_SKIP_SCHEMES) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = path if os.path.isabs(path) else os.path.join(base, path)
        if not os.path.exists(resolved):
            bad.append((target, f"missing: {os.path.normpath(resolved)}"))
    return bad


def main(argv: List[str]) -> int:
    roots = argv or ["README.md", "docs"]
    files = markdown_files(roots)
    if not files:
        print(f"error: no markdown files under {roots}", file=sys.stderr)
        return 2
    failures = 0
    for md in files:
        for target, reason in dead_links(md):
            print(f"{md}: dead link ({target}) — {reason}",
                  file=sys.stderr)
            failures += 1
    print(f"checked {len(files)} markdown file(s): "
          f"{failures or 'no'} dead link(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
