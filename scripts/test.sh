#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Sets the environment the suite expects:
#   * PYTHONPATH=src             — the repo is not pip-installed;
#   * 8 virtual host devices     — tests/test_multidevice.py spawns
#     subprocesses that re-set this themselves, but top-level collection
#     of any shard_map-using module needs >1 device available too.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

exec python -m pytest -x -q "$@"
