"""Runner: adaptive iterations, aggregates, GB-compatible JSON schema."""
import json
import time

from repro.core.registry import BenchmarkRegistry, benchmark
from repro.core.runner import RunOptions, run_benchmarks, write_json


def test_adaptive_iterations_fast_benchmark():
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def fast(state):
        while state.keep_running():
            pass

    doc = run_benchmarks(reg.all(), RunOptions(min_time=0.02),
                         progress=False)
    rec = doc["benchmarks"][0]
    assert rec["iterations"] > 100          # calibration kicked in
    assert rec["time_unit"] == "us"


def test_repetitions_and_aggregates():
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def b(state):
        while state.keep_running():
            time.sleep(0.001)

    doc = run_benchmarks(reg.all(),
                         RunOptions(min_time=0.005, repetitions=3),
                         progress=False)
    names = [r["name"] for r in doc["benchmarks"]]
    assert sum(n == "t/b" for n in names) == 3
    aggs = [r for r in doc["benchmarks"] if r["run_type"] == "aggregate"]
    assert {a["aggregate_name"] for a in aggs} == {"mean", "median",
                                                   "stddev"}


def test_error_isolation():
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def bad(state):
        raise RuntimeError("kaboom")

    @benchmark(scope="t", registry=reg)
    def good(state):
        while state.keep_running():
            pass

    doc = run_benchmarks(reg.all(), RunOptions(min_time=0.01),
                         progress=False)
    by_name = {r["name"]: r for r in doc["benchmarks"]}
    assert by_name["t/bad"]["error_occurred"] is True
    assert "t/good" in by_name and not by_name["t/good"].get(
        "error_occurred")


def test_json_schema_google_benchmark_compatible(tmp_path):
    """The schema claim from paper §V-A: unmodified GB format."""
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def b(state):
        while state.keep_running():
            pass
        state.set_bytes_processed(1024)
        state.counters["custom"] = 7.0

    doc = run_benchmarks(reg.all(), RunOptions(min_time=0.01),
                         progress=False)
    p = tmp_path / "out.json"
    write_json(doc, str(p))
    loaded = json.loads(p.read_text())
    assert set(loaded) == {"context", "benchmarks"}
    ctx = loaded["context"]
    for key in ("date", "host_name", "num_cpus"):   # GB context fields
        assert key in ctx
    rec = loaded["benchmarks"][0]
    for key in ("name", "run_name", "run_type", "iterations", "real_time",
                "cpu_time", "time_unit", "repetitions",
                "repetition_index", "threads"):
        assert key in rec, key
    assert rec["custom"] == 7.0              # counters inlined (GB style)
    assert rec["bytes_per_second"] > 0
