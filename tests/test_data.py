"""Data pipeline: determinism, resumability, host-sharding, packing."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.data import DataConfig, SyntheticLM, make_pipeline


def cfg(**kw):
    base = dict(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic():
    a = SyntheticLM(cfg()).batch(5)
    b = SyntheticLM(cfg()).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    src = SyntheticLM(cfg())
    assert not np.array_equal(src.batch(0)["tokens"],
                              src.batch(1)["tokens"])


def test_resume_replays_exactly():
    """Restart at step N yields the same stream as an uninterrupted run."""
    src = SyntheticLM(cfg())
    direct = [src.batch(i)["tokens"] for i in range(6)]
    pipe = make_pipeline(cfg(), start_step=3, prefetch=False)
    for i, (step, batch) in zip(range(3), pipe):
        assert step == 3 + i
        np.testing.assert_array_equal(batch["tokens"], direct[3 + i])


def test_host_sharding_disjoint_and_complete():
    parts = [SyntheticLM(cfg(host_count=2, host_index=h)).batch(2)
             for h in (0, 1)]
    assert all(p["tokens"].shape[0] == 2 for p in parts)
    assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLM(cfg()).batch(0)
    assert b["tokens"].shape == b["labels"].shape


@given(st.integers(0, 1000), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_tokens_in_vocab(step, batch):
    src = SyntheticLM(cfg(global_batch=batch))
    b = src.batch(step)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 1000


def test_prefetcher_yields_in_order():
    pipe = make_pipeline(cfg(), start_step=0, prefetch=True)
    steps = [next(pipe)[0] for _ in range(4)]
    pipe.close()
    assert steps == [0, 1, 2, 3]
