"""Multi-device SPMD correctness — subprocess with 8 host devices.

Covers: sharded-vs-single-device train step equivalence, shard_map MoE,
elastic resharded restore (8→4 devices).  Subprocesses because XLA locks
the device count at first jax init (the main pytest process must keep 1
device).
"""
import os
import subprocess
import sys
import textwrap

import pytest


def run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600,
                       env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import build, get_config
        from repro.train import AdamWConfig, make_train_step
        from repro.train.step import make_init_fn
        from repro.distributed import partition as part
        from repro.distributed.logical import default_rules, logical_rules

        cfg = get_config("llama3.2-1b").reduced().override(num_layers=2)
        api = build(cfg)
        opt = AdamWConfig(lr=1e-3)
        init_fn = make_init_fn(api, opt)
        step_fn = make_train_step(api, opt)
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        # single-device result
        state = init_fn(key)
        s1, m1 = jax.jit(step_fn)(state, batch)
        # sharded result
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pspecs = part.param_specs(cfg, jax.eval_shape(init_fn, key)["params"],
                                  mesh)
        shard = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        state_specs = {"params": pspecs,
                       "opt": {"m": pspecs, "v": pspecs, "count": P()},
                       "step": P()}
        with mesh, logical_rules(default_rules(cfg, mesh)):
            state2 = jax.jit(init_fn,
                             out_shardings=shard(state_specs))(key)
            s2, m2 = jax.jit(step_fn,
                             in_shardings=(shard(state_specs), None),
                             out_shardings=(shard(state_specs), None))(
                state2, batch)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 2e-3, d
        # params equal after one step
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_shard_map_matches_reference():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import layers as L
        from repro.models.config import ModelConfig
        from repro.distributed.logical import default_rules, logical_rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64,
                          vocab_size=128, moe_num_experts=8, moe_top_k=2,
                          moe_d_ff=64, moe_capacity_factor=8.0)
        p = L.init_moe(jax.random.PRNGKey(0), 32, 8, 64, 0)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        y_ref, _ = L.moe_scatter(p, x, top_k=2, capacity_factor=8.0)
        rules = default_rules(cfg, mesh)
        with mesh:
            pw = dict(p)
            for k in ("w_up", "w_gate", "w_down"):
                pw[k] = jax.device_put(p[k],
                                       NamedSharding(mesh,
                                                     P("model", None, None)))
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None,
                                                         None)))
            with logical_rules(rules):
                y, _ = jax.jit(
                    lambda p, x: L.moe_layer(p, x, cfg))(pw, xs)
        err = np.abs(np.asarray(y) - np.asarray(y_ref)).max()
        assert err < 1e-5, err
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restore_reshard(tmp_path=None):
    """Save sharded on 8 devices, restore onto a 4-device mesh."""
    out = run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint
        from repro.checkpoint.store import restore_resharded
        mesh8 = jax.make_mesh((2, 4), ("data", "model"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        spec = {"w": P("data", "model")}
        placed = jax.device_put(
            tree, jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh8, s), spec,
                is_leaf=lambda x: isinstance(x, P)))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d + "/ck", placed, step=3)
            mesh4 = jax.make_mesh((4,), ("model",))
            out, step = restore_resharded(
                d + "/ck", tree, mesh4, {"w": P("model", None)})
            assert step == 3
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(tree["w"]))
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_single_cell_small_overrides():
    """The dry-run machinery end-to-end on one cell (512 fake devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "llama3.2-1b", "--shape", "decode_32k", "--out",
         "/tmp/dryrun_test", "--tag", "pytest", "--override",
         "num_layers=2"],
        capture_output=True, text=True, timeout=580, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "compiled in" in r.stdout or "SKIP (cached)" in r.stdout
