"""ScopePlot: object model, cat/filter_name, frames, spec/deps/bar."""
import json

import pytest
import yaml
from hypothesis_compat import given, settings, st

from repro.scopeplot import BenchmarkFile, Frame, cat
from repro.scopeplot.plot import (load_spec, quick_bar, render_spec,
                                  spec_dependencies)

DOC = {
    "context": {"host_name": "h"},
    "benchmarks": [
        {"name": "s/a/n:1", "run_name": "s/a/n:1", "run_type": "iteration",
         "iterations": 10, "real_time": 5.0, "cpu_time": 5.0,
         "time_unit": "us", "bytes_per_second": 100.0},
        {"name": "s/a/n:2", "run_name": "s/a/n:2", "run_type": "iteration",
         "iterations": 10, "real_time": 7.0, "cpu_time": 7.0,
         "time_unit": "us", "bytes_per_second": 200.0},
        {"name": "s/b", "run_name": "s/b", "run_type": "iteration",
         "iterations": 1, "real_time": 9.0, "cpu_time": 9.0,
         "time_unit": "ms", "error_occurred": True, "error_message": "x"},
    ],
}


def bf():
    return BenchmarkFile.from_dict(json.loads(json.dumps(DOC)))


def test_filter_name():
    out = bf().filter_name(r"s/a")
    assert len(out) == 2
    assert all("s/a" in r.name for r in out)


def test_cat_preserves_structure():
    """Paper §V-A.4: unlike unix cat, result is valid GB JSON."""
    merged = cat([bf(), bf()])
    d = merged.to_dict()
    assert len(d["benchmarks"]) == 6
    assert d["context"] == {"host_name": "h"}
    json.dumps(d)   # serializable


def test_without_errors_and_units():
    clean = bf().without_errors()
    assert len(clean) == 2
    assert clean.records[0].real_time_seconds() == pytest.approx(5e-6)


def test_args_parsing():
    r = bf().records[0]
    assert r.arg("n") == "1"
    assert r.arg(0) == "n:1"


def test_xy_extraction():
    xs, ys = bf().without_errors().xy("n", "bytes_per_second")
    assert xs == [1.0, 2.0]
    assert ys == [100.0, 200.0]


def test_to_frame_groupby_sort():
    f = bf().without_errors().to_frame(["name", "real_time"])
    assert len(f) == 2 and f.columns == ["name", "real_time"]
    g = f.with_column("k", ["a", "a"]).groupby("k", {"real_time": sum})
    assert g["real_time"] == [12.0]
    s = f.sort_by("real_time", reverse=True)
    assert s["real_time"] == [7.0, 5.0]


@given(st.lists(st.floats(min_value=0, max_value=1e6), min_size=1,
                max_size=20))
@settings(max_examples=25, deadline=None)
def test_frame_roundtrip_csv(vals):
    f = Frame({"v": vals})
    text = f.to_csv()
    rows = text.strip().splitlines()
    assert rows[0] == "v" and len(rows) == len(vals) + 1


def test_spec_render_and_deps(tmp_path):
    src = tmp_path / "r.json"
    src.write_text(json.dumps(DOC))
    spec = {
        "title": "t", "type": "line",
        "output": str(tmp_path / "out.png"),
        "series": [{"label": "a", "input_file": str(src),
                    "regex": "s/a", "xfield": "n",
                    "yfield": "bytes_per_second"}],
    }
    sp = tmp_path / "spec.yaml"
    sp.write_text(yaml.safe_dump(spec))
    loaded = load_spec(str(sp))
    assert spec_dependencies(loaded) == [str(src)]
    render_spec(loaded)
    assert (tmp_path / "out.png").exists()


def test_bar_subcommand(tmp_path):
    src = tmp_path / "r.json"
    src.write_text(json.dumps(DOC))
    quick_bar(str(src), "n", "real_time",
              output=str(tmp_path / "bar.png"))
    assert (tmp_path / "bar.png").exists()


def test_cli_cat_filter(tmp_path, capsys):
    from repro.scopeplot.__main__ import main
    src = tmp_path / "r.json"
    src.write_text(json.dumps(DOC))
    assert main(["filter_name", str(src), "s/a"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert len(out["benchmarks"]) == 2
    assert main(["cat", str(src), str(src)]) == 0
    out = json.loads(capsys.readouterr().out)
    assert len(out["benchmarks"]) == 6


# ---------------------------------------------------------------------------
# latency_cdf: tail-percentile counters -> one CDF line per record
# ---------------------------------------------------------------------------

LATENCY_DOC = {
    "context": {"host_name": "h"},
    "benchmarks": [
        {"name": "serve/load/arrival:poisson",
         "run_name": "serve/load/arrival:poisson", "run_type": "iteration",
         "iterations": 1, "real_time": 5.0, "cpu_time": 5.0,
         "time_unit": "us",
         "latency_p50_s": 0.010, "latency_p90_s": 0.020,
         "latency_p99_s": 0.050, "latency_p999_s": 0.090,
         "ttft_p50_s": 0.004, "ttft_p99_s": 0.009},
        {"name": "serve/load/arrival:bursty",
         "run_name": "serve/load/arrival:bursty", "run_type": "iteration",
         "iterations": 1, "real_time": 5.0, "cpu_time": 5.0,
         "time_unit": "us",
         "latency_p50_s": 0.012, "latency_p90_s": 0.030,
         "latency_p99_s": 0.120, "latency_p999_s": 0.400},
        {"name": "serve/load/no-latency-counters",
         "run_name": "serve/load/no-latency-counters",
         "run_type": "iteration", "iterations": 1, "real_time": 5.0,
         "cpu_time": 5.0, "time_unit": "us"},
    ],
}


def test_latency_cdf_renders_one_line_per_record(tmp_path):
    src = tmp_path / "m.json"
    src.write_text(json.dumps(LATENCY_DOC))
    out = tmp_path / "cdf.png"
    spec = {"title": "tails", "type": "latency_cdf", "output": str(out),
            "series": [{"input_file": str(src), "regex": "serve/",
                        "xscale": 1e3}]}
    sp = tmp_path / "spec.yaml"
    sp.write_text(yaml.safe_dump(spec))
    loaded = load_spec(str(sp))
    assert spec_dependencies(loaded) == [str(src)]
    render_spec(loaded)
    assert out.exists() and out.stat().st_size > 0


def test_latency_cdf_log_tail_and_ttft_field(tmp_path):
    """y_axis scale:log flips to a 1-q survival plot; field: ttft reads
    the first-token grid instead (and records without it are skipped,
    not crashed on)."""
    src = tmp_path / "m.json"
    src.write_text(json.dumps(LATENCY_DOC))
    out = tmp_path / "ttft.png"
    spec = {"title": "ttft tails", "type": "latency_cdf",
            "output": str(out), "y_axis": {"scale": "log"},
            "series": [{"input_file": str(src), "regex": "serve/",
                        "field": "ttft"}]}
    render_spec(spec, base_dir=str(tmp_path))
    assert out.exists() and out.stat().st_size > 0


def test_latency_cdf_is_a_known_spec_type(tmp_path):
    from repro.scopeplot.plot import PLOT_TYPES
    assert "latency_cdf" in PLOT_TYPES
    sp = tmp_path / "bad.yaml"
    sp.write_text(yaml.safe_dump({"title": "x", "type": "latency_cdff",
                                  "output": "o.png", "series": []}))
    with pytest.raises(Exception, match="latency_cdf"):
        load_spec(str(sp))
