"""launch.inputs: shapes registry, applicability, struct correctness."""
import jax.numpy as jnp
import pytest

from repro.launch import inputs as inp
from repro.models import get_config, list_archs


def test_shapes_registry_matches_brief():
    assert inp.SHAPES["train_4k"].seq_len == 4096
    assert inp.SHAPES["train_4k"].global_batch == 256
    assert inp.SHAPES["prefill_32k"].global_batch == 32
    assert inp.SHAPES["decode_32k"].global_batch == 128
    assert inp.SHAPES["long_500k"].seq_len == 524288
    assert inp.SHAPES["long_500k"].global_batch == 1


def test_long_500k_applicability_per_brief():
    runs = [a for a in list_archs()
            if inp.shape_applicable(get_config(a), "long_500k")[0]]
    assert sorted(runs) == ["jamba-v0.1-52b", "mamba2-780m"]


@pytest.mark.parametrize("arch", list(list_archs()))
def test_input_structs_cover_model_inputs(arch):
    cfg = get_config(arch)
    s = inp.input_specs(cfg, "train_4k")
    assert s["tokens"].shape == (256, 4096)
    assert s["tokens"].dtype == jnp.int32
    if cfg.family == "vlm":
        assert "vision_embeds" in s and "positions" in s
        assert s["positions"].shape == (3, 256, 4096)
    if cfg.family in ("audio", "encdec"):
        assert s["frames"].shape == (256, cfg.enc_seq, cfg.d_model)
    d = inp.input_specs(cfg, "decode_32k")
    assert d["tokens"].shape == (128, 1)


def test_cache_structs_no_allocation(monkeypatch):
    cfg = get_config("llama3.2-1b")
    structs = inp.cache_structs(cfg, "decode_32k")
    assert structs["k"].shape == (16, 128, 32768, 8, 64)
    # ShapeDtypeStructs, not arrays
    assert not hasattr(structs["k"], "devices")


def test_concrete_batch_smoke():
    cfg = get_config("qwen2-vl-2b").reduced()
    b = inp.concrete_batch(cfg, "train_4k", batch_override=2,
                           seq_override=16)
    assert b["tokens"].shape == (2, 16)
    assert b["positions"].shape == (3, 2, 16)
