"""repro.store: the SQLite index mirrors history.jsonl exactly (parity,
watermark increments, deterministic rebuilds, corruption fallbacks),
ingest merges fleet shards with whole-run dedup, queries answer
byte-identically with and without the index, and the CLIs drive it all."""
import json
import os
import sqlite3

import pytest

from repro.core import history as hist
from repro.core.quantile import percentile
from repro.store import index as store_index
from repro.store import query as store_query
from repro.store.cli import query_main, store_main
from repro.store.ingest import ingest_shards
from repro.store.query import (QueryFilter, StreamStats, aggregate_records,
                               parse_percentiles, run_query, scan_records,
                               split_name)
from test_history import make_doc


@pytest.fixture
def results(tmp_path):
    """Three runs of three instances with counters, plus a tuner run."""
    d = str(tmp_path)
    for i, (bf16, f32) in enumerate([(1.0, 2.0), (1.02, 2.1),
                                     (0.98, 1.9)]):
        doc = make_doc(f"r{i}", {
            "mxu/matmul/dtype:bf16/n:256": bf16,
            "mxu/matmul/dtype:f32/n:256": f32,
            "example/saxpy/1024": 0.5 + 0.1 * i,
        }, date=f"2026-08-0{i + 1}T10:00:00")
        for b in doc["benchmarks"]:
            b["flops"] = 1e9 * (i + 1)
        hist.append_run(d, doc)
    hist.append_run(d, make_doc("t0", {"tune/matmul/bm:128": 0.9},
                                date="2026-08-04T10:00:00"), tag="tune")
    return d


def hpath(results):
    return hist.history_path(results)


def all_lines(path):
    return [line for line, _rec in hist.iter_lines(path)]


# ---------------------------------------------------------------------------
# index: watermark refresh, rebuild determinism, fallback semantics
# ---------------------------------------------------------------------------

def test_index_mirrors_scan_exactly(results):
    path = hpath(results)
    stats = store_index.refresh(path)
    assert stats.usable and stats.watermark == os.path.getsize(path)
    assert store_index.load_records(path) == hist.scan_history(path)
    assert store_index.is_fresh(path)


def test_incremental_refresh_equals_full_rebuild(results):
    path = hpath(results)
    first = store_index.refresh(path)
    # append another run: the next refresh must consume only new bytes
    hist.append_run(results, make_doc(
        "r9", {"mxu/matmul/dtype:bf16/n:256": 1.01},
        date="2026-08-05T10:00:00"))
    second = store_index.refresh(path)
    assert not second.rebuilt
    assert second.indexed == 1                     # only the new record
    assert second.watermark == os.path.getsize(path)
    incremental = store_index.load_records(path)
    store_index.rebuild(path)
    assert store_index.load_records(path) == incremental
    assert incremental == hist.scan_history(path)


def test_rebuild_is_byte_deterministic(results, tmp_path):
    path = hpath(results)
    a = str(tmp_path / "a.db")
    b = str(tmp_path / "b.db")
    store_index.rebuild(path, db_file=a)
    store_index.rebuild(path, db_file=b)
    with open(a, "rb") as fa, open(b, "rb") as fb:
        assert fa.read() == fb.read()


def test_index_droppable_without_data_loss(results):
    path = hpath(results)
    store_index.refresh(path)
    before = hist.load_history(path)
    os.remove(store_index.db_path(path))
    assert hist.load_history(path) == before       # JSONL is the truth
    store_index.refresh(path)                      # and it comes back
    assert hist.load_history(path) == before


def test_truncated_file_triggers_rebuild(results):
    path = hpath(results)
    store_index.refresh(path)
    lines = all_lines(path)
    with open(path, "w") as f:
        for line in lines[:3]:
            f.write(line + "\n")
    stats = store_index.refresh(path)
    assert stats.rebuilt and stats.total == 3
    assert store_index.load_records(path) == hist.scan_history(path)


def test_replaced_file_triggers_rebuild(results):
    path = hpath(results)
    store_index.refresh(path)
    # same size, different head bytes: the watermark would be a lie
    lines = all_lines(path)
    swapped = [lines[-1]] + lines[1:-1] + [lines[0]]
    with open(path, "w") as f:
        for line in swapped:
            f.write(line + "\n")
    stats = store_index.refresh(path)
    assert stats.rebuilt
    assert store_index.load_records(path) == hist.scan_history(path)


def test_torn_tail_left_unconsumed_then_caught_up(results):
    path = hpath(results)
    store_index.refresh(path)
    size_before = os.path.getsize(path)
    with open(path, "a") as f:
        f.write('{"run_id": "rT", "name": "s/x", "mea')     # torn write
    stats = store_index.refresh(path)
    assert stats.usable                  # unparseable tail: scan agrees
    assert stats.watermark == size_before
    # the writer finishes the line: next refresh consumes it
    with open(path, "a") as f:
        f.write('n_s": 1.0}\n')
    stats = store_index.refresh(path)
    assert stats.indexed == 1 and stats.watermark == os.path.getsize(path)
    assert store_index.load_records(path) == hist.scan_history(path)


def test_parseable_unterminated_tail_falls_back_to_scan(results):
    """A complete record missing only its newline IS data the index
    can't hold yet — the store must refuse rather than drop it."""
    path = hpath(results)
    store_index.refresh(path)
    with open(path, "a") as f:
        f.write('{"run_id": "rT", "name": "s/x", "mean_s": 1.0}')
    with pytest.raises(store_index.StoreStale):
        store_index.load_records(path)
    # load_history silently degrades to the scan and still sees it
    records = hist.load_history(path)
    assert records == hist.scan_history(path)
    assert records[-1]["run_id"] == "rT"


def test_garbage_lines_skipped_with_watermark_advanced(results):
    path = hpath(results)
    with open(path, "ab") as f:
        f.write(b'not json at all\n')
        f.write(b'\xff\xfe garbage \n')
    stats = store_index.refresh(path)
    assert stats.usable and stats.skipped == 2
    assert stats.watermark == os.path.getsize(path)
    assert store_index.load_records(path) == hist.scan_history(path)


def test_corrupt_db_falls_back_to_scan(results):
    path = hpath(results)
    store_index.refresh(path)
    with open(store_index.db_path(path), "wb") as f:
        f.write(b"this is not sqlite")
    records = hist.load_history(path)
    assert records == hist.scan_history(path)


# ---------------------------------------------------------------------------
# queries: store path byte-equivalent to the scan path
# ---------------------------------------------------------------------------

FILTERS = [
    QueryFilter(),
    QueryFilter(scope="mxu"),
    QueryFilter(family="mxu/matmul"),
    QueryFilter(name="example/saxpy/1024"),
    QueryFilter(params={"dtype": ["bf16"]}),
    QueryFilter(params={"dtype": ["bf16", "f32"]}),
    QueryFilter(tag="tune"),
    QueryFilter(tag=""),
    QueryFilter(run_id="r1"),
    QueryFilter(since="2026-08-02"),
    QueryFilter(until="2026-08-02"),
    QueryFilter(since="2026-08-02", until="2026-08-03",
                family="mxu/matmul", params={"dtype": ["f32"]}),
    QueryFilter(scope="nosuch"),
]


@pytest.mark.parametrize("flt", FILTERS, ids=lambda f: f.describe())
def test_store_and_scan_paths_byte_equivalent(results, flt):
    path = hpath(results)
    store_index.refresh(path)
    via_store = list(store_query._store_rows(path, flt))
    via_scan = list(scan_records(path, flt))
    assert via_store == via_scan                  # raw lines AND records


def test_store_and_scan_agree_on_sysinfo_filter(results):
    path = hpath(results)
    digest = hist.scan_history(path)[0]["sysinfo"]
    store_index.refresh(path)
    flt = QueryFilter(sysinfo=digest)
    assert list(store_query._store_rows(path, flt)) == \
        list(scan_records(path, flt))
    assert len(list(scan_records(path, flt))) > 0


def test_run_query_auto_uses_index_only_when_present(results):
    path = hpath(results)
    flt = QueryFilter(params={"dtype": ["bf16"]})
    # no db yet: auto must scan, not create one as a side effect
    rows = list(run_query(path, flt))
    assert not os.path.exists(store_index.db_path(path))
    assert list(run_query(path, flt, use_store="always")) == rows
    assert os.path.exists(store_index.db_path(path))
    assert list(run_query(path, flt)) == rows
    assert list(run_query(path, flt, use_store="never")) == rows


def test_split_name_typed_and_legacy():
    assert split_name("mxu/matmul/dtype:bf16/n:512") == \
        ("mxu", "mxu/matmul")
    assert split_name("example/saxpy/1024") == ("example", "example/saxpy")
    assert split_name("comm/allreduce") == ("comm", "comm/allreduce")
    assert split_name("solo") == ("solo", "solo")


def test_parse_percentiles():
    assert parse_percentiles("p50,p99,p999") == \
        [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)]
    with pytest.raises(ValueError):
        parse_percentiles("p0")
    with pytest.raises(ValueError):
        parse_percentiles("q50")
    with pytest.raises(ValueError):
        parse_percentiles("")


# ---------------------------------------------------------------------------
# streaming aggregation: Welford + P², pinned exact on small n
# ---------------------------------------------------------------------------

def test_streamstats_exact_below_five_samples():
    samples = [3.0, 1.0, 4.0, 1.5]
    st = StreamStats(parse_percentiles("p50,p90,p99"))
    for v in samples:
        st.add(v)
    out = st.result()
    assert out["n"] == 4
    assert out["mean"] == pytest.approx(sum(samples) / 4)
    assert out["min"] == 1.0 and out["max"] == 4.0
    for label, q in [("p50", 0.50), ("p90", 0.90), ("p99", 0.99)]:
        assert out[label] == pytest.approx(percentile(samples, q)), label


def test_streamstats_matches_welford_reference():
    import statistics
    samples = [0.1 * i for i in range(1, 50)]
    st = StreamStats()
    for v in samples:
        st.add(v)
    assert st.mean == pytest.approx(statistics.fmean(samples))
    assert st.stddev == pytest.approx(statistics.stdev(samples))


def test_aggregate_records_pools_counters_and_runs(results):
    path = hpath(results)
    rows = run_query(path, QueryFilter(family="mxu/matmul"),
                     use_store="never")
    aggs = {a.name: a for a in
            aggregate_records(rows, parse_percentiles("p50"))}
    bf16 = aggs["mxu/matmul/dtype:bf16/n:256"]
    assert bf16.records == 3 and bf16.runs == 3 and bf16.errors == 0
    assert bf16.mean_s.result()["mean"] == pytest.approx(1.0, rel=0.05)
    flops = bf16.counters["flops"].result()
    assert flops["n"] == 3 and flops["mean"] == pytest.approx(2e9)
    assert flops["p50"] == pytest.approx(percentile([1e9, 2e9, 3e9], 0.5))


# ---------------------------------------------------------------------------
# fleet ingest: whole-run dedup by (run_id, sysinfo)
# ---------------------------------------------------------------------------

def test_ingest_dedups_runs_across_shards(results, tmp_path):
    path = hpath(results)
    lines = all_lines(path)
    before = len(lines)
    shard_a = tmp_path / "lab-a.jsonl"
    shard_b = tmp_path / "lab-b.jsonl"
    # shard a: a known run (dup) + a new one; shard b repeats the new one
    new_run = [json.dumps({"run_id": "fleet1", "ts": "2026-08-06T00:00:00",
                           "name": "mxu/matmul/dtype:bf16/n:256",
                           "mean_s": 1.0, "stddev_s": 0.0, "n": 1,
                           "errors": 0, "sysinfo": "othermachine",
                           "verdict": "new"})]
    shard_a.write_text("\n".join([lines[0]] + new_run) + "\n")
    shard_b.write_text("\n".join(new_run) + "\n")
    stats = ingest_shards(results, [str(shard_a), str(shard_b)])
    assert stats.appended == 1                     # new run landed once
    assert stats.new_runs == [("fleet1", "othermachine")]
    assert len(stats.duplicate_runs) == 2          # r0 + cross-shard dup
    after = all_lines(path)
    assert len(after) == before + 1
    assert after[-1] == new_run[0]                 # appended verbatim
    # re-ingesting is a no-op
    again = ingest_shards(results, [str(shard_a), str(shard_b)])
    assert again.appended == 0
    assert len(all_lines(path)) == before + 1


def test_ingest_same_run_id_different_machine_keeps_both(results,
                                                         tmp_path):
    path = hpath(results)
    rec = dict(hist.scan_history(path)[0], sysinfo="machineB")
    shard = tmp_path / "b.jsonl"
    shard.write_text(json.dumps(rec) + "\n")
    stats = ingest_shards(results, [str(shard)])
    assert stats.appended == 1          # same run_id, different digest
    assert stats.new_runs == [(rec["run_id"], "machineB")]


def test_ingest_refreshes_index_incrementally(results, tmp_path):
    path = hpath(results)
    store_index.refresh(path)
    shard = tmp_path / "s.jsonl"
    shard.write_text(json.dumps(
        {"run_id": "f2", "ts": "2026-08-07T00:00:00", "name": "s/x",
         "mean_s": 1.0, "stddev_s": 0.0, "n": 1, "errors": 0,
         "sysinfo": "m2", "verdict": "new"}) + "\n")
    ingest_shards(results, [str(shard)])
    assert store_index.is_fresh(path)
    assert store_index.load_records(path) == hist.scan_history(path)


# ---------------------------------------------------------------------------
# the store fast path keeps verdicts identical
# ---------------------------------------------------------------------------

def test_compare_baseline_verdicts_unchanged_by_fast_path(results,
                                                          capsys):
    from repro.core.baseline import compare_documents, load_document
    path = hpath(results)
    contender = make_doc("new", {"mxu/matmul/dtype:bf16/n:256": 5.0,
                                 "example/saxpy/1024": 0.7})
    scan_doc = load_document(path)          # no index yet: scan path
    scan_verdicts = {c.name: c.verdict for c in
                     compare_documents(scan_doc, contender)}
    store_index.refresh(path)
    store_doc = load_document(path)         # index present: fast path
    store_verdicts = {c.name: c.verdict for c in
                      compare_documents(store_doc, contender)}
    assert store_doc == scan_doc
    assert store_verdicts == scan_verdicts
    assert store_verdicts["mxu/matmul/dtype:bf16/n:256"] == "regression"


def test_detect_drift_identical_through_store(results):
    path = hpath(results)
    records_scan = hist.load_history(path, store=False)
    store_index.refresh(path)
    records_store = hist.load_history(path)
    assert records_store == records_scan
    drift_a = hist.detect_drift(records_scan)
    drift_b = hist.detect_drift(records_store)
    assert [(c.name, c.verdict) for c in drift_a] == \
        [(c.name, c.verdict) for c in drift_b]


# ---------------------------------------------------------------------------
# CLIs
# ---------------------------------------------------------------------------

def test_store_cli_index_status_roundtrip(results, capsys):
    path = hpath(results)
    assert store_main(["index", "--results-dir", results]) == 0
    out = capsys.readouterr().out
    assert "watermark" in out
    assert store_main(["status", "--results-dir", results,
                       "--format", "json"]) == 0
    status = json.loads(capsys.readouterr().out)
    assert status["fresh"] is True
    assert status["records"] == len(hist.scan_history(path))
    assert status["runs"] == 4
    assert store_main(["index", "--results-dir", results,
                       "--rebuild"]) == 0
    assert "rebuilt" in capsys.readouterr().out


def test_query_cli_jsonl_byte_equivalent(results, capsys):
    store_index.refresh(hpath(results))
    args = ["--results-dir", results, "--param", "dtype=bf16",
            "--format", "jsonl"]
    assert query_main(args) == 0
    via_store = capsys.readouterr().out
    assert query_main(args + ["--no-store"]) == 0
    via_scan = capsys.readouterr().out
    assert via_store == via_scan
    assert len(via_store.splitlines()) == 3


def test_query_cli_json_and_aggregate(results, capsys):
    assert query_main(["--results-dir", results, "--family",
                       "mxu/matmul", "--format", "json"]) == 0
    recs = json.loads(capsys.readouterr().out)
    assert len(recs) == 6
    assert all(r["name"].startswith("mxu/matmul/") for r in recs)
    assert query_main(["--results-dir", results, "--family", "mxu/matmul",
                       "--aggregate", "--percentiles", "p50,p99",
                       "--format", "json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["records"] == 6
    by_name = {i["name"]: i for i in doc["instances"]}
    agg = by_name["mxu/matmul/dtype:f32/n:256"]
    assert agg["runs"] == 3
    assert agg["mean_s"]["p50"] == pytest.approx(2.0)
    assert agg["counters"]["flops"]["mean"] == pytest.approx(2e9)


def test_query_cli_table_and_errors(results, capsys, tmp_path):
    assert query_main(["--results-dir", results, "--tag", "tune"]) == 0
    out = capsys.readouterr().out
    assert "tune/matmul/bm:128" in out and "1 record(s)" in out
    assert query_main(["--results-dir", str(tmp_path / "void")]) == 1
    assert query_main(["--results-dir", results,
                       "--param", "notkeyvalue"]) == 2
    assert query_main(["--results-dir", results,
                       "--percentiles", "zzz"]) == 2


def test_store_cli_ingest(results, tmp_path, capsys):
    shard = tmp_path / "other.jsonl"
    shard.write_text(json.dumps(
        {"run_id": "x1", "ts": "2026-08-08T00:00:00", "name": "s/y",
         "mean_s": 2.0, "stddev_s": 0.0, "n": 1, "errors": 0,
         "sysinfo": "mX", "verdict": "new"}) + "\n")
    assert store_main(["ingest", "--results-dir", results,
                       str(shard)]) == 0
    assert "1 new run(s)" in capsys.readouterr().out
    assert store_main(["ingest", "--results-dir", results,
                       str(tmp_path / "missing.jsonl")]) == 1


def test_query_store_sql_injection_safe(results):
    """Filter values are bound parameters, never spliced into SQL."""
    path = hpath(results)
    store_index.refresh(path)
    flt = QueryFilter(family="mxu'; DROP TABLE records; --")
    assert list(store_query._store_rows(path, flt)) == []
    con = sqlite3.connect(store_index.db_path(path))
    try:
        n = con.execute("SELECT COUNT(*) FROM records").fetchone()[0]
    finally:
        con.close()
    assert n == len(hist.scan_history(path))


# ---------------------------------------------------------------------------
# v2 schema: fingerprints + cached flags (continuous benchmarking)
# ---------------------------------------------------------------------------

@pytest.fixture
def fp_results(results):
    """One extra run whose records carry fingerprints, one replayed."""
    doc = make_doc("f1", {
        "mxu/matmul/dtype:bf16/n:256": 1.01,
        "example/saxpy/1024": 0.55,
    }, date="2026-08-05T10:00:00")
    doc["context"]["fingerprints"] = {
        "mxu/matmul/dtype:bf16/n:256": "aaaa111122223333",
        "example/saxpy/1024": "bbbb111122223333",
    }
    doc["benchmarks"][1]["cached"] = True         # saxpy is a replay
    hist.append_run(results, doc)
    return results


def test_fingerprints_survive_append_and_index(fp_results):
    path = hpath(fp_results)
    recs = [r for r in hist.scan_history(path) if r["run_id"] == "f1"]
    by = {r["name"]: r for r in recs}
    assert by["mxu/matmul/dtype:bf16/n:256"]["fingerprint"] == \
        "aaaa111122223333"
    assert "cached" not in by["mxu/matmul/dtype:bf16/n:256"]
    assert by["example/saxpy/1024"]["cached"] is True
    store_index.refresh(path)
    con = sqlite3.connect(store_index.db_path(path))
    rows = dict(con.execute(
        "SELECT name, fingerprint FROM records WHERE run_id='f1'"))
    cached = dict(con.execute(
        "SELECT name, cached FROM records WHERE run_id='f1'"))
    con.close()
    assert rows["example/saxpy/1024"] == "bbbb111122223333"
    assert cached == {"mxu/matmul/dtype:bf16/n:256": 0,
                      "example/saxpy/1024": 1}


@pytest.mark.parametrize("flt", [
    QueryFilter(fingerprint="aaaa111122223333"),
    QueryFilter(fingerprint=""),
    QueryFilter(fingerprint="nosuch"),
], ids=lambda f: f.describe() or "all")
def test_fingerprint_filter_store_scan_byte_equivalent(fp_results, flt):
    path = hpath(fp_results)
    store_index.refresh(path)
    via_store = list(store_query._store_rows(path, flt))
    via_scan = list(scan_records(path, flt))
    assert via_store == via_scan
    if flt.fingerprint == "aaaa111122223333":
        assert len(via_scan) == 1


def test_query_cli_fingerprint_flag(fp_results, capsys):
    assert query_main(["--fingerprint", "bbbb111122223333",
                       "--results-dir", fp_results,
                       "--format", "json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert [r["name"] for r in out] == ["example/saxpy/1024"]


def test_store_status_counts_fingerprints(fp_results, capsys):
    assert store_main(["index", "--results-dir", fp_results]) == 0
    capsys.readouterr()
    assert store_main(["status", "--results-dir", fp_results,
                       "--format", "json"]) == 0
    status = json.loads(capsys.readouterr().out)
    assert status["fingerprints"] == 2


def test_cached_records_excluded_from_drift_pool(fp_results):
    """A replayed mean must not tighten the pooled window stddev."""
    records = hist.load_history(hpath(fp_results))
    pooled = hist.window_document(records, window=10)
    names = {b["name"]: b for b in pooled["benchmarks"]}
    # saxpy f1 record was cached: only the 3 measured runs pool
    assert names["example/saxpy/1024"]["repetitions"] == 3


def test_v1_database_rebuilds_to_v2(fp_results):
    path = hpath(fp_results)
    store_index.refresh(path)
    db = store_index.db_path(path)
    con = sqlite3.connect(db)
    con.execute("UPDATE meta SET value='1' WHERE key='schema_version'")
    con.commit()
    con.close()
    store_index.refresh(path)                    # migration-by-rebuild
    con = sqlite3.connect(db)
    version = con.execute(
        "SELECT value FROM meta WHERE key='schema_version'").fetchone()[0]
    n = con.execute("SELECT COUNT(*) FROM records "
                    "WHERE fingerprint != ''").fetchone()[0]
    con.close()
    assert version == str(store_index.SCHEMA_VERSION)
    assert n == 2


def test_store_status_coverage_table(fp_results, capsys, monkeypatch):
    from repro.store import cli as store_cli
    monkeypatch.setattr(
        store_cli, "_coverage_info",
        lambda history: {"sysinfo": "m1",
                         "scopes": {"mxu": {"fresh": 1, "stale": 2,
                                            "never": 0}},
                         "totals": {"fresh": 1, "stale": 2, "never": 0},
                         "instances": 3, "pending": ["mxu/x"]})
    assert store_main(["status", "--results-dir", fp_results,
                       "--coverage"]) == 0
    out = capsys.readouterr().out
    assert "mxu" in out and "fresh" in out
