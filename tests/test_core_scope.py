"""Scope plugin system: isolation, enable/disable, flags, hooks."""
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.registry import BenchmarkRegistry
from repro.core.scope import Scope, ScopeManager


def make_mgr():
    return ScopeManager(registry=BenchmarkRegistry(),
                        flags=FlagRegistry(), hooks=HookChain())


def test_import_failure_is_isolated():
    mgr = make_mgr()
    mgr.load(["repro.scopes.example_scope", "no.such.module"])
    status = mgr.status()
    assert status["example"] == "enabled"
    assert status["module"] == "unavailable"
    mgr.register_all()
    assert len(mgr.registry) > 0           # example still registered


def test_enable_disable():
    mgr = make_mgr()
    a = Scope(name="a", register=lambda reg: reg.register(
        __import__("repro.core.benchmark", fromlist=["Benchmark"])
        .Benchmark("a/x", lambda s: None, scope="a")))
    b = Scope(name="b", register=lambda reg: reg.register(
        __import__("repro.core.benchmark", fromlist=["Benchmark"])
        .Benchmark("b/y", lambda s: None, scope="b")))
    mgr.add_scope(a)
    mgr.add_scope(b)
    mgr.configure(disable=["b"])
    mgr.register_all()
    assert [x.name for x in mgr.registry.all()] == ["a/x"]


def test_enable_only():
    mgr = make_mgr()
    for n in "ab":
        mgr.add_scope(Scope(name=n))
    mgr.configure(enable=["b"])
    assert mgr.status() == {"a": "disabled", "b": "enabled"}


def test_enable_only_unknown_names_leaves_selection_unchanged():
    """--enable-scope with nothing but typos must not disable every scope
    — the selection stays as it was (with a warning)."""
    mgr = make_mgr()
    for n in "ab":
        mgr.add_scope(Scope(name=n))
    mgr.configure(enable=["nope", "also_nope"])
    assert mgr.status() == {"a": "enabled", "b": "enabled"}
    # a mix of known and unknown names enables the known ones only
    mgr.configure(enable=["b", "nope"])
    assert mgr.status() == {"a": "disabled", "b": "enabled"}


def test_flags_and_hooks_two_phase():
    calls = []
    flags = FlagRegistry()
    hooks = HookChain()
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=flags,
                       hooks=hooks)
    scope = Scope(
        name="s",
        declare_flags=lambda f: f.declare("s/knob", owner="s", type=int,
                                          default=3),
        pre_parse=lambda: calls.append("pre") or None,
        post_parse=lambda: calls.append("post") or None,
    )
    mgr.add_scope(scope)
    assert hooks.run_pre_parse() is None
    flags.parse(["--s.knob", "9"])
    assert hooks.run_post_parse() is None
    assert calls == ["pre", "post"]
    assert flags.get("s/knob") == 9


def test_hook_exit_code_aborts():
    hooks = HookChain()
    hooks.register_post_parse(lambda: 3, owner="s")
    assert hooks.run_post_parse() == 3


def test_example_scope_exit_flag_end_to_end():
    """Paper §IV-C: Example|Scope exits during init when flag given."""
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "-m", "repro", "--example.exit_code", "7"],
        capture_output=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                  "HOME": "/root"}, cwd=".")
    assert r.returncode == 7
