"""Per-arch smoke tests (reduced configs) + serving-consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build, get_config, list_archs
from repro.models import layers as L

ARCHS = list(list_archs())


def make_batch(cfg, B=2, S=32, key=jax.random.PRNGKey(7)):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model)) * 0.02
        batch["vision_mask"] = jnp.zeros((B, S), bool).at[:, :4].set(True)
    if cfg.family in ("audio", "encdec"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch):
    """Assigned-arch smoke: reduced config, one loss step, shapes+finite."""
    cfg = get_config(arch).reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    logits, _ = jax.jit(api.logits)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-1.7b",
                                  "deepseek-moe-16b", "mamba2-780m",
                                  "jamba-v0.1-52b", "whisper-small",
                                  "qwen2-vl-2b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode must reproduce the teacher-forced logits."""
    cfg = get_config(arch).reduced().override(moe_capacity_factor=8.0)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    batch = make_batch(cfg, B, S, jax.random.PRNGKey(2))
    full, _ = jax.jit(api.logits)(params, batch)
    pre = {k: (v[:, :S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
           for k, v in batch.items()}
    cache = api.init_cache(B, S + 4)
    lp, cache = jax.jit(api.prefill)(params, pre, cache)
    ld, cache = jax.jit(api.decode_step)(
        params, batch["tokens"][:, S - 1:S], cache)
    np.testing.assert_allclose(np.asarray(lp[:, 0], np.float32),
                               np.asarray(full[:, S - 2], np.float32),
                               atol=5e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(ld[:, 0], np.float32),
                               np.asarray(full[:, S - 1], np.float32),
                               atol=5e-2, rtol=1e-2)


def test_mrope_collapses_to_rope_for_text():
    """qwen2-vl M-RoPE with equal t/h/w positions == standard RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = L.apply_rope(x, pos, 10000.0)
    b = L.apply_rope(x, pos3, 10000.0, mrope_sections=(6, 5, 5))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_param_counts_match_known_sizes():
    expect = {"llama3.2-1b": 1.24e9, "mamba2-780m": 0.78e9,
              "stablelm-12b": 12.1e9, "jamba-v0.1-52b": 51.5e9,
              "deepseek-moe-16b": 16.9e9, "whisper-small": 0.24e9}
    for arch, n in expect.items():
        got = get_config(arch).num_params()
        assert abs(got - n) / n < 0.06, (arch, got, n)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.num_active_params() < 0.25 * cfg.num_params()


def test_config_registry_complete():
    assert len(ARCHS) == 10
    fams = {get_config(a).family for a in ARCHS}
    assert {"dense", "moe", "ssm", "hybrid", "vlm", "audio"} <= fams
