"""End-to-end training behaviour: loss decreases; resume is exact."""
from repro.launch.train import train


def test_loss_decreases():
    out = train("llama3.2-1b", steps=25, global_batch=4, seq_len=64,
                lr=1e-3, log_every=100)
    assert out["steps"] == 25
    assert out["last_loss"] < out["first_loss"] - 0.05


def test_checkpoint_resume_exact(tmp_path):
    """Interrupted+resumed run ends at the same loss as uninterrupted —
    data pipeline resumability + checkpoint fidelity together."""
    full = train("llama3.2-1b", steps=14, global_batch=2, seq_len=32,
                 lr=1e-3, ckpt_dir=None, log_every=100, seed=5)
    d2 = str(tmp_path / "b")
    train("llama3.2-1b", steps=14, global_batch=2, seq_len=32, lr=1e-3,
          ckpt_dir=d2, ckpt_every=7, log_every=100, seed=5, halt_at=7)
    resumed = train("llama3.2-1b", steps=14, global_batch=2, seq_len=32,
                    lr=1e-3, ckpt_dir=d2, ckpt_every=7, log_every=100,
                    seed=5)
    assert abs(resumed["last_loss"] - full["last_loss"]) < 2e-3


def test_microbatched_matches_unbatched():
    a = train("llama3.2-1b", steps=6, global_batch=4, seq_len=32,
              lr=1e-3, microbatches=1, log_every=100, seed=9)
    b = train("llama3.2-1b", steps=6, global_batch=4, seq_len=32,
              lr=1e-3, microbatches=2, log_every=100, seed=9)
    assert abs(a["last_loss"] - b["last_loss"]) < 5e-3
