"""Partition rules: divisibility invariants over all archs (property)."""
import jax
import jax.numpy as jnp
import pytest

from repro.distributed import partition as part
from repro.models import build, get_config, list_archs


class FakeMesh:
    """Mesh stand-in exposing .shape only (rules never touch devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16}),
          FakeMesh({"data": 2, "model": 4})]


@pytest.mark.parametrize("arch", list(list_archs()))
@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16", "2x4"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must divide by its mesh axes — the invariant that
    makes every config lower on the production mesh."""
    cfg = get_config(arch)
    api = build(cfg)
    structs = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,),
                                                            jnp.uint32))
    for specs, label in ((part.param_specs(cfg, structs, mesh), "tp"),
                         (part.zero_shard_specs(cfg, structs, mesh),
                          "zero")):
        leaves, _ = jax.tree_util.tree_flatten(structs)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index") or x is None
            or isinstance(x, tuple))
        spec_leaves = jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(lambda l, sp: (l, sp), structs, specs,
                                   is_leaf=lambda x: hasattr(x, "shape")),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and hasattr(x[0], "shape"))
        for leaf, spec in spec_leaves:
            shape = tuple(leaf.shape)
            for dim, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert shape[dim] % size == 0, (label, shape, spec)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-moe-16b",
                                  "mamba2-780m"])
def test_zero_shard_adds_data_axis_somewhere(arch):
    cfg = get_config(arch)
    api = build(cfg)
    mesh = MESHES[0]
    structs = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,),
                                                            jnp.uint32))
    part.param_specs(cfg, structs, mesh)
    zero = part.zero_shard_specs(cfg, structs, mesh)
    n_data = sum("data" in str(s) for s in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(str, zero)))
    assert n_data > 0


def test_cache_specs_cover_all_leaves():
    for arch in ("llama3.2-1b", "mamba2-780m", "jamba-v0.1-52b",
                 "whisper-small"):
        cfg = get_config(arch)
        api = build(cfg)
        cache = jax.eval_shape(lambda a=api: a.init_cache(16, 128))
        specs = part.cache_specs(cfg, cache, MESHES[0])
        n_cache = len(jax.tree_util.tree_leaves(cache))
        n_spec = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index")))
        assert n_cache == n_spec


def test_batch_spec_guards_indivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    cfg = get_config("llama3.2-1b")
    batch = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
    specs = part.input_specs_tree(cfg, batch, mesh)
    assert all(e is None for e in specs["tokens"])
