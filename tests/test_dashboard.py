"""Live dashboard (``repro report --serve``): a stdlib HTTP server over
the result store — HTML index with sparklines + drift panel, JSON query
endpoints, static report files with traversal protection.  Everything
runs against 127.0.0.1 on an ephemeral port; no matplotlib, no network
beyond loopback."""
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.core import history as hist
from repro.scopeplot.dashboard import Dashboard, create_server, sparkline_svg
from test_history import make_doc


@pytest.fixture
def results(tmp_path):
    """Two runs: the second drifts s/b by +50% (a drift-panel hit)."""
    d = str(tmp_path / "results")
    hist.append_run(d, make_doc("r1", {"s/a": 1.0, "s/b": 2.0},
                                date="2026-08-01T10:00:00"))
    hist.append_run(d, make_doc("r2", {"s/a": 1.01, "s/b": 3.0},
                                date="2026-08-02T10:00:00"))
    return d


@pytest.fixture
def server(results, tmp_path):
    report_dir = tmp_path / "report"
    report_dir.mkdir()
    (report_dir / "index.html").write_text("<html>static report</html>")
    srv = create_server(results, report_dir=str(report_dir), port=0)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    yield srv
    srv.shutdown()
    srv.server_close()
    thread.join(timeout=5)


def get(server, path, expect_json=True):
    host, port = server.server_address
    with urllib.request.urlopen(f"http://{host}:{port}{path}",
                                timeout=10) as resp:
        body = resp.read()
        return json.loads(body) if expect_json else body.decode()


def get_code(server, path):
    host, port = server.server_address
    try:
        with urllib.request.urlopen(f"http://{host}:{port}{path}",
                                    timeout=10) as resp:
            return resp.status
    except urllib.error.HTTPError as e:
        return e.code


# ---------------------------------------------------------------------------
# HTML index
# ---------------------------------------------------------------------------

def test_index_page_renders_runs_trends_and_drift(server):
    page = get(server, "/", expect_json=False)
    assert "SCOPE result store" in page
    assert "r1" in page and "r2" in page
    assert "s/a" in page and "s/b" in page
    assert "<svg" in page                      # sparklines inline
    assert "Drift watch" in page
    assert "regression" in page                # s/b drifted +50%
    assert "/report/index.html" in page        # static report linked


def test_sparkline_svg():
    svg = sparkline_svg([1.0, 2.0, 1.5])
    assert svg.startswith("<svg") and "polyline" in svg
    assert sparkline_svg([]) == ""             # empty-safe
    assert sparkline_svg([1.0]) == ""          # one point: no trend yet
    assert sparkline_svg([3.0, 3.0]) != ""     # flat series still draws


# ---------------------------------------------------------------------------
# JSON API
# ---------------------------------------------------------------------------

def test_api_runs(server):
    runs = get(server, "/api/runs")
    assert [r["run_id"] for r in runs] == ["r1", "r2"]
    assert all(r["records"] == 2 for r in runs)
    assert runs[1]["regressions"] == 1         # s/b in r2


def test_api_benchmarks_and_trend(server):
    assert get(server, "/api/benchmarks") == ["s/a", "s/b"]
    trend = get(server, "/api/trend?name=s/b")
    assert trend["name"] == "s/b"
    assert [p["mean_s"] for p in trend["points"]] == [2.0, 3.0]
    assert trend["points"][1]["verdict"] == "regression"
    assert get_code(server, "/api/trend") == 400   # name is required


def test_api_drift_matches_cli_detector(server, results):
    drift = get(server, "/api/drift")
    assert drift["latest"] == "r2" and drift["runs"] == 2
    records = hist.load_history(hist.history_path(results))
    expected = [(c.name, c.verdict) for c in hist.detect_drift(records)]
    assert [(c["name"], c["verdict"])
            for c in drift["comparisons"]] == expected
    assert {c["name"]: c["verdict"] for c in drift["comparisons"]} == \
        {"s/a": "similar", "s/b": "regression"}
    assert get(server, "/api/drift?window=3")["window"] == 3


def test_api_query_filters_and_aggregates(server):
    out = get(server, "/api/query?name=s/a")
    assert out["records"] == 2
    assert all(m["name"] == "s/a" for m in out["matches"])
    agg = get(server, "/api/query?name=s/b&aggregate=1")
    assert agg["records"] == 2
    inst = agg["instances"][0]
    assert inst["runs"] == 2
    assert inst["mean_s"]["mean"] == pytest.approx(2.5)
    assert "p50" in inst["mean_s"]
    assert get_code(server, "/api/query?param=oops") == 400


def test_api_status_reports_store_freshness(server, results):
    status = get(server, "/api/status")
    assert status["history"] == hist.history_path(results)
    assert status["exists"] is False           # no index built yet
    from repro.store.index import refresh
    refresh(hist.history_path(results))
    status = get(server, "/api/status")
    assert status["exists"] is True and status["fresh"] is True
    assert status["records"] == 4


def test_api_sees_appends_without_restart(server, results):
    hist.append_run(results, make_doc("r3", {"s/a": 1.0, "s/b": 3.1},
                                      date="2026-08-03T10:00:00"))
    runs = get(server, "/api/runs")
    assert [r["run_id"] for r in runs] == ["r1", "r2", "r3"]
    assert get(server, "/api/drift")["latest"] == "r3"


# ---------------------------------------------------------------------------
# static files + routing
# ---------------------------------------------------------------------------

def test_static_report_served(server):
    page = get(server, "/report/index.html", expect_json=False)
    assert page == "<html>static report</html>"


def test_static_traversal_rejected(server):
    assert get_code(server, "/report/../secrets.txt") == 404
    assert get_code(server, "/report/%2e%2e/secrets.txt") == 404
    assert get_code(server, "/report/nope.html") == 404


def test_unknown_endpoint_is_json_404(server):
    host, port = server.server_address
    with pytest.raises(urllib.error.HTTPError) as e:
        urllib.request.urlopen(f"http://{host}:{port}/api/nope",
                               timeout=10)
    assert e.value.code == 404
    assert json.loads(e.value.read())["error"].startswith(
        "no such endpoint")


def test_empty_results_dir_serves_empty_state(tmp_path):
    srv = create_server(str(tmp_path / "nothing"), port=0)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    try:
        page = get(srv, "/", expect_json=False)
        assert "No runs recorded yet" in page
        assert get(srv, "/api/runs") == []
        drift = get(srv, "/api/drift")
        assert drift["runs"] == 0 and drift["comparisons"] == []
    finally:
        srv.shutdown()
        srv.server_close()
        thread.join(timeout=5)


def test_dashboard_logic_without_http(results):
    """The Dashboard class is usable directly (what --serve wraps)."""
    dash = Dashboard(results)
    records = dash.records()
    assert len(records) == 4
    runs = dash.runs(records)
    assert [r["run_id"] for r in runs] == ["r1", "r2"]
    html = dash.index_html()
    assert "Instance trends" in html and "<svg" in html


def test_api_coverage_lazy_cached_and_refreshable(server):
    """Coverage is computed once (registry enumeration is heavy), cached
    across requests, and ?refresh=1 invalidates.  The registry walk is
    stubbed — HTTP plumbing is under test here, not the scopes."""
    dash = server.dashboard
    calls = []

    def fake_coverage():
        if dash._coverage is None:
            calls.append(1)
            dash._coverage = {
                "sysinfo": "m1",
                "scopes": {"s": {"fresh": 1, "stale": 1, "never": 0}},
                "totals": {"fresh": 1, "stale": 1, "never": 0},
                "instances": 2, "pending": ["s/b"]}
        return dash._coverage

    dash.coverage = fake_coverage
    first = get(server, "/api/coverage")
    assert first["totals"] == {"fresh": 1, "stale": 1, "never": 0}
    assert get(server, "/api/coverage") == first
    assert len(calls) == 1                        # cached
    get(server, "/api/coverage?refresh=1")
    assert len(calls) == 2                        # invalidated

    # once computed, the index page renders the staleness panel
    html = get(server, "/", expect_json=False)
    assert "Staleness" in html and "/api/coverage" in html


def test_api_coverage_degrades_to_error(server, monkeypatch):
    """A box that can't enumerate the registry still serves trends; the
    coverage endpoint degrades to an error payload, not a 500."""
    import repro.core.fingerprint as fing

    def boom(*a, **k):
        raise RuntimeError("no jax here")
    monkeypatch.setattr(fing, "registered_benches", boom)
    payload = get(server, "/api/coverage")
    assert "error" in payload and "no jax here" in payload["error"]
    assert get_code(server, "/") == 200           # index still serves
