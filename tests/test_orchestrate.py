"""Run orchestrator: parallel/sequential equivalence at both shard
grains, crash isolation, manifest + resume, shard merging, and
baseline-compare verdicts (repro.core.orchestrate / repro.core.baseline)."""
import json
import os
import textwrap

import pytest

from repro.core import baseline as bl
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.orchestrate import (OrchestratorOptions, ScopeShard,
                                    execute, merge_shards, read_manifest,
                                    scope_error_record)
from repro.core.registry import BenchmarkRegistry
from repro.core.runner import RunOptions, run_benchmarks
from repro.core.scope import ScopeManager

FAST = RunOptions(min_time=0.002)


def make_mgr(modules):
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(modules)
    mgr.register_all()
    return mgr


def _ensure_src_on_child_path(monkeypatch, extra=None):
    parts = [os.path.abspath("src")]
    if extra:
        parts.append(str(extra))
    old = os.environ.get("PYTHONPATH")
    if old:
        parts.append(old)
    monkeypatch.setenv("PYTHONPATH", os.pathsep.join(parts))


# ---------------------------------------------------------------------------
# equivalence
# ---------------------------------------------------------------------------

def test_inline_merged_matches_sequential_runner():
    """Orchestrated inline run == plain run_benchmarks, record for record
    (names + schema; timings vary)."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    seq = run_benchmarks(mgr.registry.filter(".*"), FAST, progress=False)
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=1, run=FAST))
    assert sorted(res.doc) == ["benchmarks", "context"]
    assert [r["name"] for r in res.doc["benchmarks"]] == \
        [r["name"] for r in seq["benchmarks"]]
    assert [frozenset(r) for r in res.doc["benchmarks"]] == \
        [frozenset(r) for r in seq["benchmarks"]]


@pytest.mark.slow
def test_parallel_subprocess_matches_inline(monkeypatch, tmp_path):
    """--jobs 2 scope-grained subprocess run: same names/schema as
    inline, per-scope shards persisted under results/<run-id>/."""
    _ensure_src_on_child_path(monkeypatch)
    mgr = make_mgr(["repro.scopes.example_scope",
                    "repro.scopes.instr_scope"])
    inline = execute(mgr, mgr.registry,
                     OrchestratorOptions(jobs=1, run=FAST))
    par = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="subprocess",
                                      shard_grain="scope", run=FAST,
                                      results_dir=str(tmp_path),
                                      run_id="t1"))
    assert [s.status for s in par.shards] == ["ok", "ok"]
    assert [r["name"] for r in par.doc["benchmarks"]] == \
        [r["name"] for r in inline.doc["benchmarks"]]
    # schema equivalence: identical key-sets per record position
    assert [frozenset(r) for r in par.doc["benchmarks"]] == \
        [frozenset(r) for r in inline.doc["benchmarks"]]
    # persistence: one shard per scope + merged.json
    out = tmp_path / "t1"
    assert sorted(p.name for p in out.iterdir()) == \
        ["example.json", "instr.json", "merged.json"]
    merged = json.loads((out / "merged.json").read_text())
    assert [s["scope"] for s in merged["context"]["shards"]] == \
        ["example", "instr"]

    # scopeplot reads run directories and merged documents
    from repro.scopeplot import load
    bf = load(str(out))
    assert bf.scope_names() == ["example", "instr"]
    assert [s["status"] for s in bf.shards()] == ["ok", "ok"]
    assert len(bf.for_scope("example")) == \
        len(load(str(out / "example.json")))


# ---------------------------------------------------------------------------
# crash isolation
# ---------------------------------------------------------------------------

CRASHY = textwrap.dedent("""
    import os
    from repro.core import Scope, State, benchmark
    from repro.core.registry import BenchmarkRegistry

    NAME = "crashy"

    def _register(registry):
        @benchmark(scope=NAME, registry=registry)
        def die(state: State):
            os._exit(42)

    SCOPE = Scope(name=NAME, register=_register)
""")


@pytest.mark.slow
def test_crash_isolation_subprocess(monkeypatch, tmp_path):
    """A scope that kills its interpreter yields a crashed shard with an
    error record; sibling scopes still complete."""
    (tmp_path / "crashy_scope.py").write_text(CRASHY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    mgr = make_mgr(["repro.scopes.example_scope", "crashy_scope"])
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="subprocess",
                                      shard_grain="scope", run=FAST))
    by = {s.scope: s for s in res.shards}
    assert by["example"].status == "ok"
    assert by["crashy"].status == "crashed"
    assert "42" in by["crashy"].error
    failed = [r for r in res.doc["benchmarks"]
              if r["name"] == "crashy/SCOPE_FAILED"]
    assert len(failed) == 1 and failed[0]["error_occurred"]
    assert any(r["name"].startswith("example/")
               for r in res.doc["benchmarks"])


FAULTY = textwrap.dedent("""
    from repro.core import Scope

    NAME = "faulty"

    def _register(registry):
        raise RuntimeError("registration exploded")

    SCOPE = Scope(name=NAME, register=_register)
""")


@pytest.mark.slow
def test_subprocess_distinguishes_error_from_crash(monkeypatch, tmp_path):
    """A worker that raises a normal exception reports an ERROR shard
    (with the traceback), not a CRASHED one."""
    (tmp_path / "faulty_scope.py").write_text(FAULTY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    make_mgr(["faulty_scope"])
    # registration failure only manifests in the worker (parent-side
    # register_all already marked it unavailable) — dispatch explicitly
    from repro.core.orchestrate import _run_subprocess
    opts = OrchestratorOptions(jobs=1, isolate="subprocess", run=FAST)
    shard = _run_subprocess("faulty", "faulty_scope", opts)
    assert shard.status == "error"
    assert "registration exploded" in shard.error


@pytest.mark.slow
def test_crash_breaks_pool_but_run_recovers(monkeypatch, tmp_path):
    """Pool mode: an interpreter-killing worker breaks the
    ProcessPoolExecutor; unfinished scopes are retried in standalone
    subprocesses and the run still produces every shard."""
    (tmp_path / "crashy_scope.py").write_text(CRASHY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    mgr = make_mgr(["repro.scopes.example_scope", "crashy_scope"])
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="pool",
                                      shard_grain="scope", run=FAST))
    by = {s.scope: s for s in res.shards}
    assert set(by) == {"example", "crashy"}
    assert by["example"].status == "ok"
    assert by["crashy"].status == "crashed"


def test_import_failure_yields_error_shard(tmp_path):
    """A scope whose import fails is reported, not silently dropped —
    and inline siblings still run."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    shards = [
        ScopeShard("example", "repro.scopes.example_scope", "ok",
                   run_benchmarks(mgr.registry.filter(".*"), FAST,
                                  progress=False)),
        ScopeShard("broken", "no.such.module", "error",
                   error="ModuleNotFoundError: no.such.module"),
    ]
    doc = merge_shards(shards, run_id="r1")
    assert doc["context"]["run_id"] == "r1"
    assert [s["status"] for s in doc["context"]["shards"]] == \
        ["ok", "error"]
    names = [r["name"] for r in doc["benchmarks"]]
    assert "broken/SCOPE_FAILED" in names


def test_scope_error_record_schema_matches_runner():
    """SCOPE_FAILED records carry the same schema as real error records
    so GB-JSON consumers need no special casing."""
    rec = scope_error_record(ScopeShard("x", "m", "crashed", error="boom"))
    for key in ("name", "run_name", "run_type", "repetitions",
                "repetition_index", "threads", "iterations", "real_time",
                "cpu_time", "time_unit", "error_occurred",
                "error_message"):
        assert key in rec
    assert rec["error_occurred"] is True
    assert "boom" in rec["error_message"]


# ---------------------------------------------------------------------------
# benchmark grain: plan scheduling, manifest, resume, instance isolation
# ---------------------------------------------------------------------------

def _names(doc):
    return [r["name"] for r in doc["benchmarks"]]


def _schemas(doc):
    return [frozenset(r) for r in doc["benchmarks"]]


def test_plan_grain_inline_matches_scope_grain(tmp_path):
    """--shard-grain benchmark produces a merged document benchmark-for-
    benchmark equivalent to a scope-grained inline run, with per-instance
    shards + a complete manifest under results/<run-id>/."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    scope_run = execute(mgr, mgr.registry,
                        OrchestratorOptions(jobs=1, run=FAST))
    plan_run = execute(mgr, mgr.registry,
                       OrchestratorOptions(jobs=1, shard_grain="benchmark",
                                           run=FAST,
                                           results_dir=str(tmp_path),
                                           run_id="p1"))
    assert _names(plan_run.doc) == _names(scope_run.doc)
    assert _schemas(plan_run.doc) == _schemas(scope_run.doc)
    # per-instance persistence: shards/<id>.json for every plan item
    out = tmp_path / "p1"
    assert (out / "merged.json").exists()
    shard_files = sorted(p.name for p in (out / "shards").iterdir()
                         if p.suffix == ".json")
    assert len(shard_files) == len(plan_run.plan.items)
    manifest = read_manifest(str(out))
    assert manifest["run_id"] == "p1"
    assert manifest["grain"] == "benchmark"
    assert manifest["completed"] == manifest["total"] == \
        len(plan_run.plan.items)
    assert [e["name"] for e in manifest["items"]] == \
        [i.name for i in plan_run.plan.items]
    assert all(e["status"] == "ok" and e["finished"] is not None
               for e in manifest["items"])
    # per-scope rollups keep scope-grained consumers working
    assert [(s.scope, s.status) for s in plan_run.shards] == \
        [("example", "ok")]
    merged = json.loads((out / "merged.json").read_text())
    assert [s["status"] for s in merged["context"]["shards"]] == ["ok"]

    # scopeplot + baseline read the instance-sharded run directory
    from repro.scopeplot import load
    assert [r.name for r in load(str(out))] == _names(plan_run.doc)
    (out / "merged.json").unlink()      # interrupted-run view
    assert _names(bl.load_document(str(out))) == _names(plan_run.doc)
    assert [r.name for r in load(str(out))] == _names(plan_run.doc)


def test_resume_skips_completed_instances(tmp_path):
    """--resume re-runs only instances whose shard is missing/failed;
    finished instances keep their manifest timestamps (proof they were
    not re-executed)."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    opts = OrchestratorOptions(jobs=1, shard_grain="benchmark", run=FAST,
                               results_dir=str(tmp_path), run_id="r1")
    first = execute(mgr, mgr.registry, opts)
    out = tmp_path / "r1"
    before = {e["name"]: e for e in read_manifest(str(out))["items"]}

    # simulate an interruption: one instance never finished
    victim = first.plan.items[2]
    (out / "shards" / f"{victim.instance_id}.json").unlink()
    (out / "merged.json").unlink()

    opts.resume = True
    second = execute(mgr, mgr.registry, opts)
    after = {e["name"]: e for e in read_manifest(str(out))["items"]}
    for name, entry in after.items():
        if name == victim.name:
            assert entry["finished"] > before[name]["finished"]
            assert not entry.get("cached")
        else:
            assert entry["finished"] == before[name]["finished"]
            assert entry.get("cached")
    # the resumed merged document is complete and in plan order
    assert _names(second.doc) == _names(first.doc)
    assert _schemas(second.doc) == _schemas(first.doc)
    assert (out / "merged.json").exists()


INSTANCE_CRASHY = textwrap.dedent("""
    import os
    from repro.core import Scope, State, benchmark

    NAME = "crashy"

    def _register(registry):
        @benchmark(scope=NAME, registry=registry)
        def ok_before(state: State):
            while state.keep_running():
                pass

        @benchmark(scope=NAME, registry=registry)
        def die(state: State):
            if state.range(0) == 2:
                os._exit(42)
            while state.keep_running():
                pass
        die.range_multiplier_args(1, 4)

        @benchmark(scope=NAME, registry=registry)
        def ok_after(state: State):
            while state.keep_running():
                pass

    SCOPE = Scope(name=NAME, register=_register)
""")


@pytest.mark.slow
def test_instance_crash_degrades_only_itself(monkeypatch, tmp_path):
    """Benchmark grain: an interpreter-killing *instance* yields an error
    record for that instance only — its family and scope siblings still
    report real records (scope grain would have lost the whole scope)."""
    # distinct module name: other tests import their own crashy_scope and
    # the parent process's module cache would serve the stale one
    (tmp_path / "instance_crashy_scope.py").write_text(INSTANCE_CRASHY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    mgr = make_mgr(["instance_crashy_scope"])
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="subprocess",
                                      shard_grain="benchmark", run=FAST))
    by = {r.item.name: r for r in res.instances}
    assert by["crashy/die/2"].status == "crashed"
    assert "42" in by["crashy/die/2"].error
    for name in ("crashy/ok_before", "crashy/die/1", "crashy/die/4",
                 "crashy/ok_after"):
        assert by[name].status == "ok"
    recs = {r["name"]: r for r in res.doc["benchmarks"]}
    assert recs["crashy/die/2"]["error_occurred"]
    assert not recs["crashy/ok_after"].get("error_occurred")
    # the scope rolls up as partial, not failed
    assert [(s.scope, s.status) for s in res.shards] == \
        [("crashy", "partial")]


@pytest.mark.slow
def test_merge_determinism_across_grains_and_resume(monkeypatch, tmp_path):
    """merged.json benchmark names/order/schema are identical across
    --jobs 1 --isolate inline, --jobs 4 --shard-grain benchmark, and a
    resumed run (the ISSUE's merge-determinism contract)."""
    _ensure_src_on_child_path(monkeypatch)
    mgr = make_mgr(["repro.scopes.example_scope",
                    "repro.scopes.instr_scope"])
    inline = execute(mgr, mgr.registry,
                     OrchestratorOptions(jobs=1, isolate="inline",
                                         run=FAST))
    par = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=4, isolate="subprocess",
                                      shard_grain="benchmark", run=FAST,
                                      results_dir=str(tmp_path),
                                      run_id="d1"))
    assert _names(par.doc) == _names(inline.doc)
    assert _schemas(par.doc) == _schemas(inline.doc)

    # interrupt: drop two instances, then resume with a different job count
    out = tmp_path / "d1"
    for item in (par.plan.items[1], par.plan.items[-1]):
        (out / "shards" / f"{item.instance_id}.json").unlink()
    (out / "merged.json").unlink()
    resumed = execute(mgr, mgr.registry,
                      OrchestratorOptions(jobs=2, isolate="subprocess",
                                          shard_grain="benchmark",
                                          run=FAST, resume=True,
                                          results_dir=str(tmp_path),
                                          run_id="d1"))
    assert sum(1 for r in resumed.instances if r.cached) == \
        len(par.plan.items) - 2
    assert _names(resumed.doc) == _names(inline.doc)
    assert _schemas(resumed.doc) == _schemas(inline.doc)
    merged = json.loads((out / "merged.json").read_text())
    assert _names(merged) == _names(inline.doc)


def test_external_scopes_run_inline_at_benchmark_grain():
    """add_scope() scopes (no importable module) can't be re-imported by
    a worker — the plan runs them inline even under --jobs N."""
    from repro.core.benchmark import Benchmark
    from repro.core.scope import Scope
    mgr = make_mgr([])
    def _register(reg):
        reg.register(Benchmark("ext/x", lambda s: None, scope="ext"))
    mgr.add_scope(Scope(name="ext", register=_register))
    mgr.register_all()
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="subprocess",
                                      shard_grain="benchmark", run=FAST))
    assert [r.item.name for r in res.instances] == ["ext/x"]
    assert res.instances[0].status == "ok"
    assert _names(res.doc) == ["ext/x"]


# ---------------------------------------------------------------------------
# baseline comparison
# ---------------------------------------------------------------------------

def _doc(entries):
    """entries: {name: [times_us...]} -> GB-JSON document."""
    benchmarks = []
    for name, times in entries.items():
        for i, t in enumerate(times):
            benchmarks.append({
                "name": name, "run_name": name, "run_type": "iteration",
                "repetitions": len(times), "repetition_index": i,
                "threads": 1, "iterations": 100,
                "real_time": t, "cpu_time": t, "time_unit": "us",
            })
    return {"context": {}, "benchmarks": benchmarks}


def test_compare_flags_2x_slowdown():
    base = _doc({"s/a": [10.0, 10.1, 9.9], "s/b": [5.0, 5.1, 4.9]})
    new = _doc({"s/a": [20.0, 20.2, 19.8], "s/b": [5.1, 5.0, 4.9]})
    comps = {c.name: c for c in bl.compare_documents(base, new)}
    assert comps["s/a"].verdict == "regression"
    assert comps["s/a"].ratio == pytest.approx(2.0, rel=0.05)
    assert comps["s/b"].verdict == "similar"


def test_compare_stddev_gates_noisy_changes():
    """A 15% mean shift inside the noise band must NOT be flagged."""
    base = _doc({"s/noisy": [10.0, 14.0, 6.0]})
    new = _doc({"s/noisy": [11.5, 16.0, 7.0]})
    (c,) = bl.compare_documents(base, new)
    assert c.verdict == "similar" and not c.significant


def test_compare_improvement_added_removed_errors():
    base = _doc({"s/fast": [10.0, 10.0, 10.0], "s/gone": [1.0]})
    new = _doc({"s/fast": [5.0, 5.0, 5.0], "s/new": [1.0]})
    new["benchmarks"].append({
        "name": "s/err", "run_name": "s/err", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
        "time_unit": "us", "error_occurred": True, "error_message": "x"})
    base["benchmarks"].append(dict(new["benchmarks"][-1]))
    comps = {c.name: c for c in bl.compare_documents(base, new)}
    assert comps["s/fast"].verdict == "improvement"
    assert comps["s/gone"].verdict == "removed"
    assert comps["s/new"].verdict == "added"
    assert comps["s/err"].verdict == "errors"


def test_compare_units_normalized():
    base = {"context": {}, "benchmarks": [{
        "name": "s/x", "run_name": "s/x", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 1, "real_time": 1.0, "cpu_time": 1.0,
        "time_unit": "ms"}]}
    new = {"context": {}, "benchmarks": [{
        "name": "s/x", "run_name": "s/x", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 1, "real_time": 1000.0, "cpu_time": 1000.0,
        "time_unit": "us"}]}
    (c,) = bl.compare_documents(base, new)
    assert c.verdict == "similar"
    assert c.ratio == pytest.approx(1.0)


def test_compare_cli_exit_codes(tmp_path, capsys):
    base = _doc({"s/a": [10.0, 10.0, 10.1]})
    slow = _doc({"s/a": [20.0, 20.0, 20.2]})
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(base))
    pb.write_text(json.dumps(slow))
    assert bl.compare_main([str(pa), str(pb)]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    assert bl.compare_main([str(pa), str(pa)]) == 0


def test_gate_fails_on_vanished_or_errored_benchmarks():
    """A crashed scope (benchmarks vanish or turn into error records in
    the contender) must fail the CI gate, not slide through as
    'removed'/'added'."""
    base = _doc({"s/a": [10.0], "s/b": [10.0]})
    vanished = _doc({"s/a": [10.0]})
    assert [c.name for c in
            bl.gate_failures(bl.compare_documents(base, vanished))] == \
        ["s/b"]
    errored = _doc({"s/a": [10.0]})
    errored["benchmarks"].append({
        "name": "s/b", "run_name": "s/b", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
        "time_unit": "us", "error_occurred": True, "error_message": "x"})
    assert [c.name for c in
            bl.gate_failures(bl.compare_documents(base, errored))] == \
        ["s/b"]
    # already broken in the baseline → not a new failure
    base_broken = _doc({"s/a": [10.0]})
    base_broken["benchmarks"].append(dict(errored["benchmarks"][-1]))
    assert bl.gate_failures(
        bl.compare_documents(base_broken, errored)) == []


def test_load_document_reads_interrupted_run_dir(tmp_path):
    """A run directory without merged.json (crash mid-run) still loads:
    the per-scope shards are concatenated."""
    a = _doc({"s/a": [1.0]})
    b = _doc({"s/b": [2.0]})
    (tmp_path / "a.json").write_text(json.dumps(a))
    (tmp_path / "b.json").write_text(json.dumps(b))
    doc = bl.load_document(str(tmp_path))
    assert [r["name"] for r in doc["benchmarks"]] == ["s/a", "s/b"]


def test_aggregates_are_not_double_counted():
    doc = _doc({"s/a": [10.0, 10.0]})
    doc["benchmarks"].append({
        "name": "s/a_mean", "run_name": "s/a", "run_type": "aggregate",
        "aggregate_name": "mean", "repetitions": 2, "threads": 1,
        "iterations": 100, "real_time": 10.0, "cpu_time": 10.0,
        "time_unit": "us"})
    stats = bl.collect_stats(doc)
    assert stats["s/a"].n == 2
